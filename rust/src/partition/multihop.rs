//! Multi-hop model splitting over a relay path (PR 10): K nested cuts
//! instead of one.
//!
//! *Pipelining Split Learning in Multi-hop Edge Networks* (arxiv
//! 2505.04368) generalizes the paper's device→server split to a **path**
//! of H = K+1 hosts — the device, K−1 relay hosts, and the final server —
//! joined by K links. A placement assigns every layer a host, monotone
//! along the layer DAG, which is exactly K *nested* lower-set cuts
//! `L_1 ⊆ L_2 ⊆ … ⊆ L_K` (cut k = the layers on hosts `< k`, i.e. the
//! "device side" of hop k). The training delay generalizes Eq. (7): each
//! layer pays its host's compute rate, each hop k carries cut k's smashed
//! activations up / gradients down `N_loc` times plus the parameters of
//! every layer below the hop once up and once down.
//!
//! The engine rides on a **stage separability** identity: with stage k's
//! single-split cost graph `G_k` (ξ_D = host k−1's compute vector, ξ_S =
//! host k's, everything else shared) and `T_k` its ordinary Eq. (7)
//! delay under hop k's link,
//!
//! ```text
//! T(L_1..L_K) = Σ_k T_k(L_k) − N_loc · Σ_v Σ_{0<h<K} ξ_h[v]
//! ```
//!
//! — the path delay is a sum of K *independent* single-split problems
//! minus a constant (each relay host's full-model compute is counted once
//! extra by the telescoping sum; [`PathSpec::offset`]). Minimizing the
//! path delay is therefore minimizing Σ_k T_k(L_k) **subject to
//! nesting**, and dropping the nesting constraint yields a lower bound
//! solved by K warm-started min cuts ([`super::fleet::FleetPlanner`] per
//! hop). [`PathPlanner::plan`] runs a strategy ladder on top of that:
//!
//! 1. **K = 1** delegates to a single-tier engine with the exact
//!    [`super::planner::PartitionPlanner`] construction — bit-identical
//!    decisions, solves and flow shape (the degenerate pin).
//! 2. **Separable fast path**: solve each stage unconstrained; if the K
//!    optima happen to nest, they achieve the relaxation bound — the plan
//!    is certified optimal without any joint search.
//! 3. **Exact DP** over the enumerated lower-set lattice (when it has at
//!    most [`PathOptions::exact_cuts`] sets): `dp[k][c] = T_k(c) +
//!    min_{c' ⊆ c} dp[k−1][c']` — the best prefix delay ending segment k
//!    at cut c, each transition a subset test on bitmask words, counted
//!    in [`super::fleet::FleetStats::dp_transitions`]. Optimal because
//!    feasible placements are exactly the nested chains of the lattice.
//! 4. **Pooling fallback** for unenumerable lattices: merge the first
//!    adjacent stage pair whose unconstrained optima violate nesting —
//!    contracting the relay host between them out of the path, the two
//!    hop links composing serially ([`super::types::Link::serial`];
//!    σ adds) — and re-solve, until the surviving segments nest (at worst
//!    the whole path collapses to one device→server split). The result
//!    is feasible by construction and carries
//!    [`PathPlan::certified`] = true only when its cost meets the
//!    separable lower bound.
//!
//! [`oracle_path_delay`] is the independent brute force the harness pins
//! the planner against: enumerate *every* nested K-tuple of lower-set
//! cuts by odometer and take the best Σ_k T_k − offset.

use std::collections::BTreeMap;

use super::fleet::{FleetOptions, FleetPlanner, FleetSpec, FleetStats};
use super::types::{Link, Problem};
use crate::graph::enumerate_lower_sets_capped;
use crate::profiles::CostGraph;
use crate::util::prop::CUT_COST_ULPS;

/// Raw lower-set cap of [`oracle_path_delay`]'s enumeration (the planner's
/// DP bound is the independent [`PathOptions::exact_cuts`]).
const ORACLE_CUT_CAP: usize = 4096;

/// Nested-tuple budget of the brute-force oracle (mirrors the 5M
/// cut-combination guard of `partition::joint`'s fleet oracle).
const ORACLE_COMBO_CAP: u64 = 5_000_000;

/// A relay path: per-host compute vectors over one shared model, plus the
/// derived per-hop single-split stage graphs.
#[derive(Clone)]
pub struct PathSpec {
    /// `host_xi[h][v]`: layer v's compute time on host h (host 0 = the
    /// device, the last host = the final server).
    host_xi: Vec<Vec<f64>>,
    /// Stage k's cost graph: ξ_D = `host_xi[k]`, ξ_S = `host_xi[k+1]`,
    /// DAG / activation bytes / parameter bytes / N_loc shared with the
    /// template. Stage 0 of a two-host path is the template itself.
    stages: Vec<CostGraph>,
    /// The relay double-count constant `N_loc · Σ_v Σ_{0<h<K} ξ_h[v]`:
    /// `Σ_k T_k(L_k) = T(L_1..L_K) + offset` (module docs). 0.0 for a
    /// two-host path.
    offset: f64,
}

impl PathSpec {
    /// Build a path from a template cost graph (supplying the DAG, byte
    /// profiles and `N_loc`) and one compute vector per host. At least
    /// two hosts; every vector must cover every layer with finite,
    /// non-negative times.
    pub fn new(template: &CostGraph, host_xi: Vec<Vec<f64>>) -> PathSpec {
        assert!(host_xi.len() >= 2, "a path needs at least two hosts");
        for (h, xi) in host_xi.iter().enumerate() {
            assert_eq!(
                xi.len(),
                template.len(),
                "host {h} compute vector must cover every layer"
            );
            for (v, &x) in xi.iter().enumerate() {
                assert!(
                    x.is_finite() && x >= 0.0,
                    "host {h} layer {v} compute {x} must be finite and non-negative"
                );
            }
        }
        let stages: Vec<CostGraph> = (0..host_xi.len() - 1)
            .map(|k| {
                let mut c = template.clone();
                c.xi_d = host_xi[k].clone();
                c.xi_s = host_xi[k + 1].clone();
                c
            })
            .collect();
        let mut inner = 0.0;
        for xi in &host_xi[1..host_xi.len() - 1] {
            for &x in xi {
                inner += x;
            }
        }
        let offset = template.n_loc * inner;
        PathSpec {
            host_xi,
            stages,
            offset,
        }
    }

    /// The K = 1 degenerate path: device and server straight from the
    /// cost graph. `stage_costs(0)` is then `costs` verbatim and
    /// [`PathSpec::offset`] is exactly 0.0, so path evaluation reproduces
    /// [`Problem::delay`] bit-for-bit.
    pub fn single(costs: &CostGraph) -> PathSpec {
        PathSpec::new(costs, vec![costs.xi_d.clone(), costs.xi_s.clone()])
    }

    /// A synthetic relay ladder: `relays` intermediate hosts whose
    /// per-layer compute interpolates geometrically between the device's
    /// ξ_D and the server's ξ_S (relay h of a (relays+2)-host path runs
    /// layer v in `ξ_D[v]^(1−t) · ξ_S[v]^t` with `t = h/(relays+1)`). The
    /// endpoints are the original vectors verbatim, so `relayed(c, 0)`
    /// is exactly [`PathSpec::single`]`(c)`.
    pub fn relayed(costs: &CostGraph, relays: usize) -> PathSpec {
        let hosts = relays + 2;
        let mut host_xi = Vec::with_capacity(hosts);
        host_xi.push(costs.xi_d.clone());
        for h in 1..hosts - 1 {
            let t = h as f64 / (hosts - 1) as f64;
            host_xi.push(
                (0..costs.len())
                    .map(|v| costs.xi_d[v].powf(1.0 - t) * costs.xi_s[v].powf(t))
                    .collect(),
            );
        }
        host_xi.push(costs.xi_s.clone());
        PathSpec::new(costs, host_xi)
    }

    /// Number of hops (= segments = cuts) K; hosts() − 1.
    pub fn hops(&self) -> usize {
        self.stages.len()
    }

    /// Number of hosts H = K + 1.
    pub fn hosts(&self) -> usize {
        self.host_xi.len()
    }

    /// Number of model layers.
    pub fn len(&self) -> usize {
        self.stages[0].len()
    }

    /// True iff the model has no layers (never for profiled models).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Hop k's single-split stage cost graph (module docs).
    pub fn stage_costs(&self, k: usize) -> &CostGraph {
        &self.stages[k]
    }

    /// Host h's per-layer compute vector.
    pub fn host_xi(&self, h: usize) -> &[f64] {
        &self.host_xi[h]
    }

    /// The relay double-count constant (module docs).
    pub fn offset(&self) -> f64 {
        self.offset
    }

    /// The pooled stage graph spanning hops `a..=b`: the single-split
    /// problem that remains when relay hosts `a+1..=b` are contracted out
    /// of the path (ξ_D = host a, ξ_S = host b+1); its link is the serial
    /// composition of the hops' links ([`Link::serial`]).
    fn pooled_costs(&self, a: usize, b: usize) -> CostGraph {
        let mut c = self.stages[a].clone();
        c.xi_s = self.host_xi[b + 1].clone();
        c
    }

    /// Host of every layer under nested per-hop cuts: the first hop whose
    /// device side contains the layer (the last host if none does).
    pub fn host_of(&self, cuts: &[Vec<bool>]) -> Vec<usize> {
        assert_eq!(cuts.len(), self.hops());
        let k = self.hops();
        (0..self.len())
            .map(|v| (0..k).find(|&j| cuts[j][v]).unwrap_or(k))
            .collect()
    }

    /// Canonical path delay of nested per-hop cuts: `Σ_k T_k(L_k) −
    /// offset`, each stage evaluated through [`Problem::delay`] (the
    /// association the planner and the oracle share). Asserts nesting and
    /// per-stage feasibility (lower sets, pinned sources).
    pub fn delay_of_cuts(&self, cuts: &[Vec<bool>], links: &[Link]) -> f64 {
        let k = self.hops();
        assert_eq!(cuts.len(), k, "one cut per hop");
        assert_eq!(links.len(), k, "one link per hop");
        for j in 0..k - 1 {
            assert!(
                subset(&cuts[j], &cuts[j + 1]),
                "cuts must nest: hop {j} ⊄ hop {}",
                j + 1
            );
        }
        let mut sum = 0.0;
        for j in 0..k {
            let problem = Problem::new(self.stage_costs(j), links[j]);
            assert!(
                problem.is_feasible(&cuts[j]),
                "hop {j} cut is not a pinned lower set"
            );
            sum += problem.delay(&cuts[j]);
        }
        sum - self.offset
    }

    /// Direct semantic evaluation of a host assignment — compute at each
    /// layer's host, per-hop boundary activations `N_loc` times up and
    /// down, per-hop downstream parameters once each way. The ground
    /// truth [`PathSpec::delay_of_cuts`] is pinned against (they agree
    /// within the usual ULP tolerance; the associations differ).
    pub fn delay_of_hosts(&self, host_of: &[usize], links: &[Link]) -> f64 {
        let n = self.len();
        let k = self.hops();
        assert_eq!(host_of.len(), n);
        assert_eq!(links.len(), k);
        let c = &self.stages[0];
        for e in c.dag.edges() {
            assert!(
                host_of[e.from] <= host_of[e.to],
                "host assignment must be monotone along edge {} -> {}",
                e.from,
                e.to
            );
        }
        for v in 0..n {
            assert!(host_of[v] <= k, "layer {v} on unknown host {}", host_of[v]);
            assert!(
                c.dag.in_degree(v) > 0 || host_of[v] == 0,
                "pinned source layer {v} must run on the device"
            );
        }
        let mut compute = 0.0;
        for v in 0..n {
            compute += self.host_xi[host_of[v]][v];
        }
        let mut transit = 0.0;
        for (j, link) in links.iter().enumerate() {
            let mut boundary_bytes = 0.0;
            let mut below_param_bytes = 0.0;
            for v in 0..n {
                if host_of[v] > j {
                    continue;
                }
                below_param_bytes += c.param_bytes[v];
                let crosses = c
                    .dag
                    .out_edges(v)
                    .iter()
                    .any(|&e| host_of[c.dag.edge(e).to] > j);
                if crosses {
                    boundary_bytes += c.act_bytes[v];
                }
            }
            transit += c.n_loc * (boundary_bytes / link.up_bps + boundary_bytes / link.down_bps)
                + below_param_bytes / link.up_bps
                + below_param_bytes / link.down_bps;
        }
        c.n_loc * compute + transit
    }
}

/// Construction switches of [`PathPlanner`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PathOptions {
    /// Exact-DP bound: the nested-cut DP runs whenever the model's raw
    /// lower-set lattice has at most this many sets (probed with
    /// [`enumerate_lower_sets_capped`], so an exploding lattice costs
    /// O(bound), not O(lattice)). Chains always qualify (n+1 prefixes);
    /// branchy zoo models fall through to the pooling ladder. 0 disables
    /// the DP outright (the pooling-path tests use it).
    pub exact_cuts: usize,
}

impl Default for PathOptions {
    fn default() -> Self {
        PathOptions { exact_cuts: 512 }
    }
}

/// One multi-hop plan: K nested cuts, the induced per-layer hosts, the
/// canonical path delay, and whether it carries an optimality certificate.
#[derive(Clone, Debug)]
pub struct PathPlan {
    /// `cuts[j][v]`: layer v is below hop j (on hosts ≤ j). Nested.
    pub cuts: Vec<Vec<bool>>,
    /// Host of every layer (`host_of[v] ∈ 0..=K`).
    pub host_of: Vec<usize>,
    /// Canonical path delay (`Σ_k T_k − offset`; see
    /// [`PathSpec::delay_of_cuts`]).
    pub delay: f64,
    /// True when the plan is provably optimal: the K = 1 exact solve, the
    /// separable fast path (per-hop optima nested — the relaxation bound
    /// is met), or the exact nested-cut DP. False only when the pooling
    /// fallback finished above the separable lower bound.
    pub certified: bool,
}

/// The K-segment path planner (module docs for the strategy ladder).
pub struct PathPlanner {
    spec: PathSpec,
    engine: Engine,
    solves: u64,
}

enum Engine {
    /// K = 1: the exact [`super::planner::PartitionPlanner`] construction
    /// (one-tier fleet engine, reduction and incremental re-solves off).
    Single(FleetPlanner),
    Multi(MultiEngine),
}

struct MultiEngine {
    /// One warm single-tier engine per hop (stages differ in ξ_S, and
    /// fleet tiers share one server vector — so one engine per stage).
    /// Reduction stays off (Theorem 2's argument assumes the server side
    /// never computes slower than the device side, which a relay ladder
    /// can invert); incremental re-solves stay on (the PR-4 warm path —
    /// σ-only epochs reuse each stage's previous flow).
    stages: Vec<FleetPlanner>,
    /// Lazily built engines for pooled hop spans `a..=b` (pooling path).
    pooled: BTreeMap<(usize, usize), FleetPlanner>,
    /// The enumerated pin-feasible lower-set lattice (sets + bitmask
    /// words), when within [`PathOptions::exact_cuts`].
    cuts: Option<(Vec<Vec<bool>>, Vec<Vec<u64>>)>,
    dp_transitions: u64,
}

impl PathPlanner {
    /// Build with default options.
    pub fn new(spec: PathSpec) -> PathPlanner {
        PathPlanner::with_options(spec, PathOptions::default())
    }

    pub fn with_options(spec: PathSpec, options: PathOptions) -> PathPlanner {
        let engine = if spec.hops() == 1 {
            // The PartitionPlanner construction, verbatim (its degenerate
            // bit-identity contract).
            Engine::Single(FleetPlanner::with_options(
                FleetSpec::single(spec.stage_costs(0).clone()),
                FleetOptions {
                    pin_inputs: true,
                    closure_edges: true,
                    ..FleetOptions::bit_identical()
                },
            ))
        } else {
            let stages = (0..spec.hops())
                .map(|k| {
                    FleetPlanner::with_options(
                        FleetSpec::single(spec.stage_costs(k).clone()),
                        FleetOptions {
                            block_reduction: false,
                            ..FleetOptions::default()
                        },
                    )
                })
                .collect();
            Engine::Multi(MultiEngine {
                stages,
                pooled: BTreeMap::new(),
                cuts: feasible_cuts(spec.stage_costs(0), options.exact_cuts),
                dp_transitions: 0,
            })
        };
        PathPlanner {
            spec,
            engine,
            solves: 0,
        }
    }

    /// Plan the K-segment split for the current per-hop links (one link
    /// per hop, device side first).
    pub fn plan(&mut self, links: &[Link]) -> PathPlan {
        assert_eq!(links.len(), self.spec.hops(), "one link per hop");
        for l in links {
            assert!(l.is_valid(), "rates must be positive and finite");
        }
        self.solves += 1;
        match &mut self.engine {
            Engine::Single(fleet) => {
                let part = fleet.take_solve(0, links[0]);
                let host_of = part.device_set.iter().map(|&d| usize::from(!d)).collect();
                PathPlan {
                    host_of,
                    cuts: vec![part.device_set],
                    delay: part.delay,
                    certified: true,
                }
            }
            Engine::Multi(m) => m.plan(&self.spec, links),
        }
    }

    /// The path this planner serves.
    pub fn spec(&self) -> &PathSpec {
        &self.spec
    }

    /// Number of [`PathPlanner::plan`] calls served.
    pub fn solves(&self) -> u64 {
        self.solves
    }

    /// (vertices, edges) of hop 0's flow-network shape; `None` on the
    /// linear fast path (matches `PartitionPlanner::flow_size` at K = 1).
    pub fn flow_size(&self) -> Option<(usize, usize)> {
        match &self.engine {
            Engine::Single(f) => f.flow_size(),
            Engine::Multi(m) => m.stages[0].flow_size(),
        }
    }

    /// Aggregate counters: every stage (and pooled) engine's additive
    /// [`FleetStats`] counters folded together, DAG-shape fields from hop
    /// 0's engine, plus this planner's `dp_transitions`. At K = 1 this is
    /// the inner engine's stats verbatim (all three topology counters 0 —
    /// the degenerate pin).
    pub fn stats(&self) -> FleetStats {
        match &self.engine {
            Engine::Single(f) => f.stats(),
            Engine::Multi(m) => {
                let mut s = m.stages[0].stats();
                for e in m.stages.iter().skip(1).chain(m.pooled.values()) {
                    fold_counters(&mut s, &e.stats());
                }
                s.dp_transitions = m.dp_transitions;
                s
            }
        }
    }
}

impl MultiEngine {
    fn plan(&mut self, spec: &PathSpec, links: &[Link]) -> PathPlan {
        let k = spec.hops();
        // Separable relaxation: each stage solved unconstrained by its
        // warm engine. The sum (minus the offset) lower-bounds every
        // nested plan.
        let parts: Vec<_> = (0..k)
            .map(|i| self.stages[i].take_solve(0, links[i]))
            .collect();
        let mut sum = 0.0;
        for p in &parts {
            sum += p.delay;
        }
        let bound = sum - spec.offset();
        if (0..k - 1).all(|i| subset(&parts[i].device_set, &parts[i + 1].device_set)) {
            let cuts: Vec<Vec<bool>> = parts.into_iter().map(|p| p.device_set).collect();
            return PathPlan {
                host_of: spec.host_of(&cuts),
                cuts,
                delay: bound,
                certified: true,
            };
        }
        if self.cuts.is_some() {
            self.plan_dp(spec, links)
        } else {
            self.plan_pooled(spec, links, bound)
        }
    }

    /// Exact DP over the enumerated lattice (module docs, strategy 3).
    fn plan_dp(&mut self, spec: &PathSpec, links: &[Link]) -> PathPlan {
        let (cut_sets, masks) = self.cuts.as_ref().expect("dp requires the lattice");
        let k = spec.hops();
        let c = cut_sets.len();
        // Per-stage cost tables through the shared Problem::delay
        // association.
        let f: Vec<Vec<f64>> = (0..k)
            .map(|i| {
                let problem = Problem::new(spec.stage_costs(i), links[i]);
                cut_sets.iter().map(|s| problem.delay(s)).collect()
            })
            .collect();
        let mut dp = f[0].clone();
        let mut parents: Vec<Vec<usize>> = Vec::with_capacity(k - 1);
        for stage in 1..k {
            let mut next = vec![f64::INFINITY; c];
            let mut parent = vec![usize::MAX; c];
            for j in 0..c {
                let mut best = f64::INFINITY;
                let mut arg = usize::MAX;
                for p in 0..c {
                    if !mask_subset(&masks[p], &masks[j]) {
                        continue;
                    }
                    self.dp_transitions += 1;
                    if dp[p] < best {
                        best = dp[p];
                        arg = p;
                    }
                }
                // Every cut is a subset of itself, so a predecessor
                // always exists.
                next[j] = best + f[stage][j];
                parent[j] = arg;
            }
            parents.push(parent);
            dp = next;
        }
        let mut best = 0;
        for j in 1..c {
            if dp[j] < dp[best] {
                best = j;
            }
        }
        let mut idx = vec![0usize; k];
        idx[k - 1] = best;
        for stage in (1..k).rev() {
            idx[stage - 1] = parents[stage - 1][idx[stage]];
        }
        let cuts: Vec<Vec<bool>> = idx.iter().map(|&i| cut_sets[i].clone()).collect();
        let mut sum = 0.0;
        for (stage, &i) in idx.iter().enumerate() {
            sum += f[stage][i];
        }
        PathPlan {
            host_of: spec.host_of(&cuts),
            cuts,
            delay: sum - spec.offset(),
            certified: true,
        }
    }

    /// Pooling fallback (module docs, strategy 4): repeatedly contract
    /// the first nesting violation's relay host until the surviving
    /// segment optima nest. Terminates in at most K−1 merges.
    fn plan_pooled(&mut self, spec: &PathSpec, links: &[Link], bound: f64) -> PathPlan {
        let k = spec.hops();
        let mut segs: Vec<(usize, usize)> = (0..k).map(|i| (i, i)).collect();
        loop {
            let mut seg_cuts = Vec::with_capacity(segs.len());
            for &(a, b) in &segs {
                let link = links[a..=b].iter().copied().reduce(Link::serial).unwrap();
                seg_cuts.push(self.segment_engine(spec, a, b).take_solve(0, link));
            }
            let violation = (0..segs.len().saturating_sub(1))
                .find(|&i| !subset(&seg_cuts[i].device_set, &seg_cuts[i + 1].device_set));
            match violation {
                Some(i) => {
                    let merged = (segs[i].0, segs[i + 1].1);
                    segs.splice(i..=i + 1, [merged]);
                }
                None => {
                    let mut cuts = Vec::with_capacity(k);
                    for (s, &(a, b)) in segs.iter().enumerate() {
                        for _ in a..=b {
                            cuts.push(seg_cuts[s].device_set.clone());
                        }
                    }
                    let delay = spec.delay_of_cuts(&cuts, links);
                    let tol = CUT_COST_ULPS * f64::EPSILON * (1.0 + delay.abs().max(bound.abs()));
                    return PathPlan {
                        host_of: spec.host_of(&cuts),
                        certified: delay <= bound + tol,
                        cuts,
                        delay,
                    };
                }
            }
        }
    }

    /// The warm engine for hop span `a..=b`: a per-hop stage engine for a
    /// singleton span, else a lazily built (and cached — pooling patterns
    /// recur across epochs) engine on the contracted stage graph.
    fn segment_engine(&mut self, spec: &PathSpec, a: usize, b: usize) -> &mut FleetPlanner {
        if a == b {
            return &mut self.stages[a];
        }
        self.pooled.entry((a, b)).or_insert_with(|| {
            FleetPlanner::with_options(
                FleetSpec::single(spec.pooled_costs(a, b)),
                FleetOptions {
                    block_reduction: false,
                    ..FleetOptions::default()
                },
            )
        })
    }
}

/// Brute-force optimum of the K-segment split: enumerate every nested
/// K-tuple of pin-feasible lower-set cuts by odometer and return the best
/// canonical path delay. Deliberately independent of the planner's DP
/// recurrence (the harness pins one against the other). Panics when the
/// lattice exceeds [`ORACLE_CUT_CAP`] sets or the tuple space exceeds
/// [`ORACLE_COMBO_CAP`] — oracle instances must stay small.
pub fn oracle_path_delay(spec: &PathSpec, links: &[Link]) -> f64 {
    let k = spec.hops();
    assert_eq!(links.len(), k, "one link per hop");
    let (cut_sets, masks) = feasible_cuts(spec.stage_costs(0), ORACLE_CUT_CAP)
        .expect("oracle requires an enumerable lower-set lattice");
    let c = cut_sets.len();
    let combos = (c as u64).saturating_pow(k as u32);
    assert!(
        combos <= ORACLE_COMBO_CAP,
        "oracle limited to {ORACLE_COMBO_CAP} cut combinations, got {combos}"
    );
    let f: Vec<Vec<f64>> = (0..k)
        .map(|i| {
            let problem = Problem::new(spec.stage_costs(i), links[i]);
            cut_sets.iter().map(|s| problem.delay(s)).collect()
        })
        .collect();
    let mut idx = vec![0usize; k];
    let mut best = f64::INFINITY;
    loop {
        let nested = (0..k - 1).all(|j| mask_subset(&masks[idx[j]], &masks[idx[j + 1]]));
        if nested {
            let mut sum = 0.0;
            for (stage, &i) in idx.iter().enumerate() {
                sum += f[stage][i];
            }
            let delay = sum - spec.offset();
            if delay < best {
                best = delay;
            }
        }
        // Odometer over the full tuple space.
        let mut pos = 0;
        while pos < k {
            idx[pos] += 1;
            if idx[pos] < c {
                break;
            }
            idx[pos] = 0;
            pos += 1;
        }
        if pos == k {
            break;
        }
    }
    assert!(best.is_finite(), "no feasible nested tuple (empty lattice?)");
    best
}

/// The pin-feasible lower-set lattice of a stage graph (every lower set
/// containing all pinned source layers), as membership masks plus packed
/// bitmask words — `None` when the raw lattice exceeds `cap`.
fn feasible_cuts(costs: &CostGraph, cap: usize) -> Option<(Vec<Vec<bool>>, Vec<Vec<u64>>)> {
    let raw = enumerate_lower_sets_capped(&costs.dag, cap)?;
    let sets: Vec<Vec<bool>> = raw
        .into_iter()
        .filter(|s| (0..costs.len()).all(|v| costs.dag.in_degree(v) > 0 || s[v]))
        .collect();
    let masks = sets.iter().map(|s| to_mask(s)).collect();
    Some((sets, masks))
}

fn to_mask(set: &[bool]) -> Vec<u64> {
    let mut words = vec![0u64; set.len().div_ceil(64)];
    for (v, &m) in set.iter().enumerate() {
        if m {
            words[v / 64] |= 1u64 << (v % 64);
        }
    }
    words
}

fn mask_subset(a: &[u64], b: &[u64]) -> bool {
    a.iter().zip(b).all(|(&x, &y)| x & !y == 0)
}

fn subset(a: &[bool], b: &[bool]) -> bool {
    a.iter().zip(b).all(|(&x, &y)| !x || y)
}

/// Fold `other`'s additive counters into `acc`, leaving `acc`'s DAG-shape
/// fields (vertex/edge/block counts) untouched — the aggregation
/// [`PathPlanner::stats`] and `partition::assign` share.
pub(crate) fn fold_counters(acc: &mut FleetStats, other: &FleetStats) {
    acc.plans += other.plans;
    acc.requests += other.requests;
    acc.refreshes += other.refreshes;
    acc.flow_solves += other.flow_solves;
    acc.linear_scans += other.linear_scans;
    acc.incremental_solves += other.incremental_solves;
    acc.repair_pushes += other.repair_pushes;
    acc.augment_rounds += other.augment_rounds;
    acc.price_iterations += other.price_iterations;
    acc.joint_resolves += other.joint_resolves;
    acc.fallback_cold_solves += other.fallback_cold_solves;
    acc.spec_deltas += other.spec_deltas;
    acc.retired_decisions += other.retired_decisions;
    acc.degraded_decisions += other.degraded_decisions;
    acc.quantized_requests += other.quantized_requests;
    acc.dp_transitions += other.dp_transitions;
    acc.assignment_moves += other.assignment_moves;
    acc.inner_makespan_solves += other.inner_makespan_solves;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Dag;
    use crate::models;
    use crate::partition::planner::PartitionPlanner;
    use crate::profiles::{DeviceProfile, TrainCfg};
    use crate::util::prop::{
        assert_fleet_cost_equal, for_all, random_layer_dag, random_link, random_path, zoo_matrix,
        CUT_COST_ULPS,
    };
    use crate::util::rng::Rng;

    fn cg(model: &str) -> CostGraph {
        let m = models::by_name(model).unwrap();
        CostGraph::build(
            &m,
            &DeviceProfile::jetson_tx2(),
            &DeviceProfile::rtx_a6000(),
            &TrainCfg::default(),
        )
    }

    /// A random synthetic cost graph over a random layer DAG — small
    /// enough that the DP path stays exact.
    fn random_costs(rng: &mut Rng, n: usize) -> CostGraph {
        let mut dag = Dag::new();
        for i in 0..n {
            dag.add_node(format!("v{i}"));
        }
        for (u, v) in random_layer_dag(rng, n, 0.2) {
            dag.add_edge(u, v, 1.0);
        }
        CostGraph {
            dag,
            xi_d: (0..n).map(|_| rng.range(1e-3, 1e-1)).collect(),
            xi_s: (0..n).map(|_| rng.range(1e-5, 1e-3)).collect(),
            act_bytes: (0..n).map(|_| rng.range(1e3, 1e6)).collect(),
            param_bytes: (0..n).map(|_| rng.range(1e3, 1e6)).collect(),
            n_loc: 4.0,
        }
    }

    /// A random ladder over `costs` with `hosts` hosts: endpoints from
    /// the graph, relays drawn between the two regimes.
    fn random_ladder(rng: &mut Rng, costs: &CostGraph, hosts: usize) -> PathSpec {
        let n = costs.len();
        let mut host_xi = vec![costs.xi_d.clone()];
        for _ in 1..hosts - 1 {
            host_xi.push((0..n).map(|_| rng.range(1e-5, 1e-1)).collect());
        }
        host_xi.push(costs.xi_s.clone());
        PathSpec::new(costs, host_xi)
    }

    /// The two path-delay formulations — canonical stage sum minus offset
    /// vs direct host-assignment semantics — agree on random nested cuts.
    #[test]
    fn stage_delay_sum_matches_direct_host_evaluation() {
        for_all("path-delay-formulations", 32, |rng| {
            let costs = random_costs(rng, 2 + rng.index(6));
            let hosts = 3 + rng.index(2);
            let spec = random_ladder(rng, &costs, hosts);
            let k = spec.hops();
            let links = random_path(rng, k);
            // Random monotone host assignment with pinned sources.
            let n = costs.len();
            let order = costs.dag.topo_order().unwrap();
            let mut host_of = vec![0usize; n];
            for &v in &order {
                let floor = costs
                    .dag
                    .parents(v)
                    .into_iter()
                    .map(|p| host_of[p])
                    .max()
                    .unwrap_or(0);
                host_of[v] = if costs.dag.in_degree(v) == 0 {
                    0
                } else {
                    floor + rng.index(k + 1 - floor)
                };
            }
            let cuts: Vec<Vec<bool>> = (0..k)
                .map(|j| (0..n).map(|v| host_of[v] <= j).collect())
                .collect();
            let canonical = spec.delay_of_cuts(&cuts, &links);
            let direct = spec.delay_of_hosts(&host_of, &links);
            assert_fleet_cost_equal(canonical, direct, "path delay formulations");
            assert_eq!(spec.host_of(&cuts), host_of);
        });
    }

    /// A two-host path is the single-split problem verbatim: zero offset,
    /// stage 0 the original graph, bit-identical delay.
    #[test]
    fn two_host_path_reproduces_the_single_split_bitwise() {
        let costs = cg("lenet5");
        let spec = PathSpec::single(&costs);
        assert_eq!(spec.hops(), 1);
        assert_eq!(spec.offset(), 0.0);
        let link = Link::symmetric(2e6);
        let problem = Problem::new(&costs, link);
        let mut prefix = vec![false; costs.len()];
        prefix[0] = true;
        for cut in [vec![true; costs.len()], prefix] {
            let path = spec.delay_of_cuts(std::slice::from_ref(&cut), &[link]);
            assert_eq!(path.to_bits(), problem.delay(&cut).to_bits());
        }
    }

    /// The degenerate pin: at K = 1 the path planner IS the partition
    /// planner — decisions, solve count and flow shape bit-identical, the
    /// topology counters pinned at zero.
    #[test]
    fn k1_planner_is_bit_identical_to_partition_planner() {
        zoo_matrix("multihop-k1-degenerate", |case, rng| {
            let mut path = PathPlanner::new(PathSpec::single(&case.costs));
            let mut flat = PartitionPlanner::new(&case.costs);
            assert_eq!(path.flow_size(), flat.flow_size());
            for _ in 0..13 {
                let link = random_link(rng);
                let plan = path.plan(&[link]);
                let want = flat.partition(link);
                assert_eq!(plan.cuts.len(), 1);
                assert_eq!(plan.cuts[0], want.device_set);
                assert_eq!(plan.delay.to_bits(), want.delay.to_bits());
                assert!(plan.certified);
                for (v, &h) in plan.host_of.iter().enumerate() {
                    assert_eq!(h == 0, want.device_set[v]);
                }
            }
            assert_eq!(path.solves(), flat.solves());
            assert_eq!(path.flow_size(), flat.flow_size());
            let stats = path.stats();
            assert_eq!(stats.dp_transitions, 0);
            assert_eq!(stats.assignment_moves, 0);
            assert_eq!(stats.inner_makespan_solves, 0);
        });
    }

    /// The oracle pin: on every zoo cell whose lower-set lattice is
    /// enumerable, 2- and 3-hop plans are certified and match the
    /// brute-force nested-tuple optimum.
    #[test]
    fn planner_matches_oracle_on_enumerable_zoo_paths() {
        zoo_matrix("multihop-oracle-equivalence", |case, rng| {
            let lattice = enumerate_lower_sets_capped(&case.costs.dag, 512);
            let Some(lattice) = lattice else {
                return; // branchy model: the DP bound (and the oracle) pass
            };
            for hops in [2usize, 3] {
                if (lattice.len() as u64).saturating_pow(hops as u32) > 2_000_000 {
                    continue;
                }
                let spec = PathSpec::relayed(&case.costs, hops - 1);
                let mut planner = PathPlanner::new(spec.clone());
                for draw in 0..3 {
                    let links = random_path(rng, hops);
                    let plan = planner.plan(&links);
                    assert!(
                        plan.certified,
                        "{}/{} draw {draw}: enumerable lattice must certify",
                        case.model, case.tier
                    );
                    let oracle = oracle_path_delay(&spec, &links);
                    assert_fleet_cost_equal(
                        plan.delay,
                        oracle,
                        &format!("{}/{} hops {hops} draw {draw}", case.model, case.tier),
                    );
                    // The reported delay is the canonical evaluation of
                    // the reported cuts.
                    assert_fleet_cost_equal(
                        plan.delay,
                        spec.delay_of_cuts(&plan.cuts, &links),
                        "reported delay vs reported cuts",
                    );
                }
            }
        });
    }

    /// An anti-nested ladder (fast device, terrible relay, fast server)
    /// must leave the separable fast path, run the DP, bypass the relay
    /// entirely, and still match the oracle.
    #[test]
    fn dp_path_fires_on_non_nested_ladders_and_skips_the_bad_relay() {
        let costs = cg("lenet5");
        let n = costs.len();
        let huge = vec![1.0; n]; // a relay ~10^4x slower than either end
        let spec = PathSpec::new(&costs, vec![costs.xi_d.clone(), huge, costs.xi_s.clone()]);
        let mut planner = PathPlanner::new(spec.clone());
        let links = [Link::symmetric(5e6), Link::symmetric(4e6)];
        let plan = planner.plan(&links);
        assert!(plan.certified);
        let stats = planner.stats();
        assert!(
            stats.dp_transitions > 0,
            "anti-nested stage optima must force the DP"
        );
        assert!(
            plan.host_of.iter().all(|&h| h != 1),
            "no layer may run on the pathological relay: {:?}",
            plan.host_of
        );
        assert_fleet_cost_equal(
            plan.delay,
            oracle_path_delay(&spec, &links),
            "anti-nested ladder",
        );
    }

    /// Widening any hop's rates never raises a certified path makespan,
    /// and warm re-plans on the same planner stay certified.
    #[test]
    fn hop_widening_never_raises_the_path_makespan() {
        for_all("multihop-monotonicity", 24, |rng| {
            let costs = random_costs(rng, 2 + rng.index(5));
            let spec = random_ladder(rng, &costs, 3);
            let mut planner = PathPlanner::new(spec);
            let links = random_path(rng, 2);
            let base = planner.plan(&links);
            assert!(base.certified, "small lattices must certify");
            for widen in 0..2 {
                let mut wider = links.clone();
                wider[widen].up_bps = (wider[widen].up_bps * 4.0).min(1e9);
                wider[widen].down_bps = (wider[widen].down_bps * 4.0).min(1e9);
                let plan = planner.plan(&wider);
                assert!(plan.certified);
                let tol =
                    CUT_COST_ULPS * f64::EPSILON * (1.0 + base.delay.abs().max(plan.delay.abs()));
                assert!(
                    plan.delay <= base.delay + tol,
                    "widening hop {widen} raised the makespan: {} -> {}",
                    base.delay,
                    plan.delay
                );
            }
        });
    }

    /// With the DP disabled the pooling ladder must still return a
    /// feasible nested plan, never beat the brute-force optimum, and
    /// collapse anti-nested paths to fewer distinct cuts.
    #[test]
    fn pooling_fallback_is_feasible_and_never_beats_the_oracle() {
        let costs = cg("lenet5");
        let n = costs.len();
        let huge = vec![1.0; n];
        let spec = PathSpec::new(&costs, vec![costs.xi_d.clone(), huge, costs.xi_s.clone()]);
        let mut planner = PathPlanner::with_options(spec.clone(), PathOptions { exact_cuts: 0 });
        let links = [Link::symmetric(5e6), Link::symmetric(4e6)];
        let plan = planner.plan(&links);
        // Feasibility: delay_of_cuts re-asserts nesting + lower sets.
        let reported = spec.delay_of_cuts(&plan.cuts, &links);
        assert_eq!(reported.to_bits(), plan.delay.to_bits());
        assert_eq!(
            plan.cuts[0], plan.cuts[1],
            "pooling an anti-nested 2-hop path must merge its segments"
        );
        let oracle = oracle_path_delay(&spec, &links);
        let tol = CUT_COST_ULPS * f64::EPSILON * (1.0 + oracle.abs().max(plan.delay.abs()));
        assert!(
            plan.delay + tol >= oracle,
            "pooling may be suboptimal but never better than brute force: {} vs {oracle}",
            plan.delay
        );
        assert_eq!(planner.stats().dp_transitions, 0);
    }

    /// The interpolated relay ladder keeps the endpoints verbatim (so
    /// `relayed(c, 0) == single(c)`) and every relay between the two
    /// regimes.
    #[test]
    fn relayed_ladder_interpolates_between_exact_endpoints() {
        let costs = cg("googlenet");
        let spec = PathSpec::relayed(&costs, 2);
        assert_eq!(spec.hosts(), 4);
        assert_eq!(spec.host_xi(0), &costs.xi_d[..]);
        assert_eq!(spec.host_xi(3), &costs.xi_s[..]);
        for h in 1..3 {
            for v in 0..costs.len() {
                let (lo, hi) = if costs.xi_d[v] <= costs.xi_s[v] {
                    (costs.xi_d[v], costs.xi_s[v])
                } else {
                    (costs.xi_s[v], costs.xi_d[v])
                };
                let x = spec.host_xi(h)[v];
                assert!(
                    (lo..=hi).contains(&x),
                    "relay {h} layer {v}: {x} outside [{lo}, {hi}]"
                );
            }
        }
        let degenerate = PathSpec::relayed(&costs, 0);
        assert_eq!(degenerate.hops(), 1);
        assert_eq!(degenerate.host_xi(0), &costs.xi_d[..]);
        assert_eq!(degenerate.host_xi(1), &costs.xi_s[..]);
    }
}
