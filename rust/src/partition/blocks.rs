//! Alg. 3: block detection.
//!
//! A block is a branching-reconvergence region: a parent vertex with
//! multiple children whose parallel paths converge again at a single
//! vertex (Sec. VI-A.1). Detection walks from every multi-child vertex to
//! its *immediate post-dominator* — the first vertex every path to the
//! output must pass through — and collects the vertices strictly between,
//! plus the converged vertex (as in Alg. 3 line 10).
//!
//! Detected blocks are only usable for abstraction if they are *closed*:
//! no internal vertex (other than the convergence vertex) feeds a vertex
//! outside the block. Repetition is established by a structural signature
//! (sequence of layer-kind labels + internal edge shape), mirroring the
//! paper's "if G_B appears multiple times, it is retained as a reusable
//! unit".

use crate::graph::{Dag, NodeId};

/// One detected block.
#[derive(Clone, Debug, PartialEq)]
pub struct Block {
    /// The branching vertex feeding the block (the block's v_in).
    pub input: NodeId,
    /// Internal members, including the convergence vertex, excluding `input`.
    pub members: Vec<NodeId>,
    /// The convergence vertex (last member in topological order).
    pub output: NodeId,
    /// Structural signature for repetition grouping.
    pub signature: String,
}

/// Detect all closed branching-reconvergence blocks in a layer DAG.
///
/// Blocks are returned in topological order of their input vertex and are
/// pairwise non-overlapping (when candidates overlap, the earlier/input-most
/// one wins; nested candidates are skipped).
pub fn detect_blocks(dag: &Dag) -> Vec<Block> {
    let order = dag.topo_order().expect("layer graphs are acyclic");
    let n = dag.len();
    let mut pos = vec![0usize; n];
    for (i, &v) in order.iter().enumerate() {
        pos[v] = i;
    }

    let ipdom = immediate_post_dominators(dag, &order);

    // `claimed` marks block *members*; a member may still be the *input* of
    // the following block (e.g. chained inception outputs in GoogLeNet).
    let mut claimed = vec![false; n];
    let mut blocks = Vec::new();
    for &v in &order {
        if dag.out_degree(v) < 2 {
            continue;
        }
        let Some(conv) = ipdom[v] else { continue };
        // Collect vertices strictly between v and conv: descendants of v
        // that are ancestors of conv.
        let desc = dag.descendants(v);
        let anc = dag.ancestors(conv);
        let mut members: Vec<NodeId> = (0..n)
            .filter(|&u| u != v && desc[u] && anc[u])
            .collect();
        members.sort_by_key(|&u| pos[u]);
        if members.len() < 2 {
            continue; // degenerate (e.g. direct edge v -> conv only)
        }
        // Closedness: members other than conv must not feed outside.
        let member_set: Vec<bool> = {
            let mut s = vec![false; n];
            for &u in &members {
                s[u] = true;
            }
            s
        };
        let closed = members.iter().all(|&u| {
            u == conv
                || dag
                    .children(u)
                    .iter()
                    .all(|&ch| member_set[ch])
        });
        if !closed {
            continue;
        }
        // Non-overlap with already-claimed blocks.
        if members.iter().any(|&u| claimed[u]) {
            continue;
        }
        for &u in &members {
            claimed[u] = true;
        }
        let signature = block_signature(dag, v, &members, &pos);
        blocks.push(Block {
            input: v,
            members,
            output: conv,
            signature,
        });
    }
    blocks
}

/// Group blocks by signature; returns (signature, block indices) for
/// signatures appearing at least `min_repeats` times.
pub fn repeated_blocks(blocks: &[Block], min_repeats: usize) -> Vec<Vec<usize>> {
    let mut groups: std::collections::BTreeMap<&str, Vec<usize>> = Default::default();
    for (i, b) in blocks.iter().enumerate() {
        groups.entry(&b.signature).or_default().push(i);
    }
    groups
        .into_values()
        .filter(|g| g.len() >= min_repeats)
        .collect()
}

/// Immediate post-dominator of every vertex, or `None` for output vertices.
///
/// Computed on the reverse graph with the classic Cooper-Harvey-Kennedy
/// iterative intersection over reverse-topological order. Multiple outputs
/// are handled with a virtual exit.
pub fn immediate_post_dominators(dag: &Dag, order: &[NodeId]) -> Vec<Option<NodeId>> {
    let n = dag.len();
    let mut pos = vec![0usize; n];
    for (i, &v) in order.iter().enumerate() {
        pos[v] = i;
    }
    let virtual_exit = n; // virtual vertex post-dominating everything
    let mut idom: Vec<Option<usize>> = vec![None; n + 1];
    idom[virtual_exit] = Some(virtual_exit);

    // Successors in the post-dominance sense = children, outputs -> exit.
    let succs = |v: usize| -> Vec<usize> {
        if dag.out_degree(v) == 0 {
            vec![virtual_exit]
        } else {
            dag.children(v)
        }
    };
    // Process in reverse topological order until fixpoint (one pass
    // suffices on DAGs, but iterate for safety).
    let rpo_pos = |v: usize| -> usize {
        if v == virtual_exit {
            usize::MAX
        } else {
            pos[v]
        }
    };
    let intersect = |idom: &[Option<usize>], mut a: usize, mut b: usize| -> usize {
        // Walk up the post-dominator tree: idom steps increase the topo
        // position (toward the exit), so the *smaller*-position node climbs.
        while a != b {
            while rpo_pos(a) < rpo_pos(b) {
                a = idom[a].expect("processed");
            }
            while rpo_pos(b) < rpo_pos(a) {
                b = idom[b].expect("processed");
            }
        }
        a
    };
    let mut changed = true;
    while changed {
        changed = false;
        for &v in order.iter().rev() {
            let mut new_idom: Option<usize> = None;
            for s in succs(v) {
                if idom[s].is_none() {
                    continue;
                }
                new_idom = Some(match new_idom {
                    None => s,
                    Some(cur) => intersect(&idom, cur, s),
                });
            }
            if new_idom.is_some() && idom[v] != new_idom {
                idom[v] = new_idom;
                changed = true;
            }
        }
    }
    (0..n)
        .map(|v| match idom[v] {
            Some(d) if d != virtual_exit => Some(d),
            _ => None,
        })
        .collect()
}

fn block_signature(dag: &Dag, input: NodeId, members: &[NodeId], pos: &[usize]) -> String {
    // Kind tags in topological order + edge structure relative to the
    // member ordering. Layer labels are "<tag>_<id>"; strip the id.
    let tag = |v: NodeId| -> &str {
        let l = dag.label(v);
        l.split('_').next().unwrap_or(l)
    };
    let index_of = |v: NodeId| -> Option<usize> {
        members.iter().position(|&u| u == v)
    };
    let mut sig = String::new();
    sig.push_str(tag(input));
    sig.push('|');
    let mut sorted = members.to_vec();
    sorted.sort_by_key(|&u| pos[u]);
    for &u in &sorted {
        sig.push_str(tag(u));
        sig.push('(');
        let mut kids: Vec<String> = dag
            .children(u)
            .iter()
            .filter_map(|&c| index_of(c).map(|i| i.to_string()))
            .collect();
        kids.sort();
        sig.push_str(&kids.join(","));
        sig.push(')');
    }
    sig
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;

    #[test]
    fn ipdom_of_diamond() {
        let mut g = Dag::new();
        for i in 0..4 {
            g.add_node(format!("v{i}"));
        }
        g.add_edge(0, 1, 0.0);
        g.add_edge(0, 2, 0.0);
        g.add_edge(1, 3, 0.0);
        g.add_edge(2, 3, 0.0);
        let order = g.topo_order().unwrap();
        let ipdom = immediate_post_dominators(&g, &order);
        assert_eq!(ipdom[0], Some(3));
        assert_eq!(ipdom[1], Some(3));
        assert_eq!(ipdom[2], Some(3));
        assert_eq!(ipdom[3], None);
    }

    /// A pure chain has no branching vertex, so nothing to abstract: every
    /// vertex stays its own "block" in the reduced sense, and the
    /// post-dominator of each vertex is simply its successor.
    #[test]
    fn pure_chain_every_vertex_is_its_own_block() {
        let mut g = Dag::new();
        for i in 0..5 {
            g.add_node(format!("v{i}"));
        }
        for i in 0..4 {
            g.add_edge(i, i + 1, 0.0);
        }
        assert!(detect_blocks(&g).is_empty());
        let order = g.topo_order().unwrap();
        let ipdom = immediate_post_dominators(&g, &order);
        for v in 0..4 {
            assert_eq!(ipdom[v], Some(v + 1), "chain ipdom is the successor");
        }
        assert_eq!(ipdom[4], None, "the output has no post-dominator");
    }

    /// The smallest closed block: a skip edge around one layer
    /// (`0 -> 1 -> 2` plus `0 -> 2`). Its two members are the single
    /// branch layer and the convergence vertex.
    #[test]
    fn detects_single_layer_branch_block() {
        let mut g = Dag::new();
        for i in 0..3 {
            g.add_node(format!("v{i}"));
        }
        g.add_edge(0, 1, 0.0);
        g.add_edge(1, 2, 0.0);
        g.add_edge(0, 2, 0.0);
        let blocks = detect_blocks(&g);
        assert_eq!(blocks.len(), 1);
        assert_eq!(blocks[0].input, 0);
        assert_eq!(blocks[0].members, vec![1, 2]);
        assert_eq!(blocks[0].output, 2);
    }

    /// Nested candidates: an outer diamond whose left branch is itself a
    /// diamond. Detection walks inputs in topological order, so the
    /// input-most (outer) candidate claims the vertices and the nested
    /// inner candidate is skipped.
    #[test]
    fn nested_candidates_resolve_to_the_outer_block() {
        let mut g = Dag::new();
        for i in 0..7 {
            g.add_node(format!("v{i}"));
        }
        // Outer: 0 -> {1, 4} -> 6; inner: 1 -> {2, 3} -> 5.
        g.add_edge(0, 1, 0.0);
        g.add_edge(0, 4, 0.0);
        g.add_edge(1, 2, 0.0);
        g.add_edge(1, 3, 0.0);
        g.add_edge(2, 5, 0.0);
        g.add_edge(3, 5, 0.0);
        g.add_edge(5, 6, 0.0);
        g.add_edge(4, 6, 0.0);
        let blocks = detect_blocks(&g);
        assert_eq!(blocks.len(), 1, "inner candidate must be skipped");
        assert_eq!(blocks[0].input, 0);
        assert_eq!(blocks[0].output, 6);
        // Members are ordered by topological position; compare as a set.
        let mut members = blocks[0].members.clone();
        members.sort_unstable();
        assert_eq!(members, vec![1, 2, 3, 4, 5, 6]);
    }

    /// Overlapping candidates at a shared boundary: the convergence vertex
    /// of one block may serve as the *input* of the next (GoogLeNet chains
    /// inceptions this way), so both are detected — members never overlap,
    /// boundary vertices may.
    #[test]
    fn chained_blocks_share_boundary_vertices() {
        let mut g = Dag::new();
        for i in 0..7 {
            g.add_node(format!("v{i}"));
        }
        // 0 -> {1, 2} -> 3 -> {4, 5} -> 6.
        g.add_edge(0, 1, 0.0);
        g.add_edge(0, 2, 0.0);
        g.add_edge(1, 3, 0.0);
        g.add_edge(2, 3, 0.0);
        g.add_edge(3, 4, 0.0);
        g.add_edge(3, 5, 0.0);
        g.add_edge(4, 6, 0.0);
        g.add_edge(5, 6, 0.0);
        let blocks = detect_blocks(&g);
        assert_eq!(blocks.len(), 2);
        assert_eq!(blocks[0].input, 0);
        assert_eq!(blocks[0].output, 3);
        assert_eq!(blocks[1].input, 3, "block 0's output feeds block 1");
        assert_eq!(blocks[1].output, 6);
        let m0: std::collections::HashSet<_> = blocks[0].members.iter().collect();
        assert!(blocks[1].members.iter().all(|m| !m0.contains(m)));
    }

    /// GPT-2's transformer stack: every pre-norm block splits into an
    /// attention sub-block and an MLP sub-block; `repeated_blocks` must
    /// group the 12 structurally identical repetitions of each so they are
    /// retained as reusable units.
    #[test]
    fn gpt2_repeated_blocks_form_twelve_wide_groups() {
        let m = models::by_name("gpt2").unwrap();
        let blocks = detect_blocks(m.dag());
        assert!(blocks.len() >= 24, "2 sub-blocks per transformer block");
        let groups = repeated_blocks(&blocks, 2);
        assert!(
            groups.iter().any(|g| g.len() >= 12),
            "no 12-wide repeated group: {:?}",
            groups.iter().map(|g| g.len()).collect::<Vec<_>>()
        );
        let grouped: usize = groups.iter().map(|g| g.len()).sum();
        assert!(grouped >= 22, "repetition grouping too sparse: {grouped}");
    }

    #[test]
    fn detects_declared_blocks_in_zoo_models() {
        // Structural detection must find at least as many block instances
        // as the architecture builders declared, for every block model.
        for (name, declared) in [
            ("resnet18", 8usize),
            ("resnet50", 16),
            ("googlenet", 9),
            ("densenet121", 58),
            ("gpt2", 12),
        ] {
            let m = models::by_name(name).unwrap();
            let blocks = detect_blocks(m.dag());
            assert!(
                blocks.len() >= declared,
                "{name}: detected {} blocks, declared {declared}",
                blocks.len()
            );
        }
    }

    #[test]
    fn detected_blocks_are_repeated_in_resnet() {
        let m = models::by_name("resnet18").unwrap();
        let blocks = detect_blocks(m.dag());
        let groups = repeated_blocks(&blocks, 2);
        // ResNet18 has identity blocks repeated within stages.
        assert!(!groups.is_empty());
        for g in &groups {
            assert!(g.len() >= 2);
        }
    }

    #[test]
    fn blocks_do_not_overlap_and_are_closed() {
        for name in ["resnet50", "googlenet", "densenet121", "gpt2"] {
            let m = models::by_name(name).unwrap();
            let dag = m.dag();
            let blocks = detect_blocks(dag);
            let mut claimed = vec![false; m.len()];
            for b in &blocks {
                let member_set: std::collections::HashSet<_> =
                    b.members.iter().copied().collect();
                for &u in &b.members {
                    assert!(!claimed[u], "{name}: overlap at {u}");
                    claimed[u] = true;
                    if u != b.output {
                        for ch in dag.children(u) {
                            assert!(
                                member_set.contains(&ch),
                                "{name}: member {u} leaks to {ch}"
                            );
                        }
                    }
                }
                assert_eq!(*b.members.last().unwrap(), b.output);
            }
        }
    }

    #[test]
    fn linear_model_has_no_blocks() {
        let m = models::by_name("lenet5").unwrap();
        assert!(detect_blocks(m.dag()).is_empty());
    }

    #[test]
    fn single_block_nets_detect_one_block() {
        for name in models::BLOCK_NETS {
            let m = models::by_name(name).unwrap();
            let blocks = detect_blocks(m.dag());
            assert_eq!(blocks.len(), 1, "{name}");
            // Matches the declared ground truth.
            let declared: std::collections::HashSet<_> =
                m.declared_blocks()[0].iter().copied().collect();
            let found: std::collections::HashSet<_> =
                blocks[0].members.iter().copied().collect();
            assert_eq!(declared, found, "{name}");
        }
    }
}
