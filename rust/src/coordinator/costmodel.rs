//! Cost graph of the L2 split model, so the paper's partitioning algorithm
//! can choose among the compiled cut points.
//!
//! The four stages of `python/compile/model.py` become a 5-vertex chain
//! (input + 4 stages) whose per-stage FLOPs / parameter / activation sizes
//! are derived from the same geometry the AOT manifest declares. Because
//! the chain is linear, every feasible partition is a prefix, and prefix
//! length k maps 1:1 onto artifact cut k (0 = central, 4 = device-only).

use crate::graph::Dag;
use crate::models::{LayerKind, ModelGraph, Shape};
use crate::partition::Partition;
use crate::profiles::{CostGraph, DeviceProfile, TrainCfg};
use crate::runtime::Manifest;

/// The L2 model as a zoo-style [`ModelGraph`] (input + 4 stages).
pub fn l2_model(manifest: &Manifest) -> ModelGraph {
    let (mut m, input) = ModelGraph::new(
        "l2-split-cnn",
        Shape::chw(manifest.channels, manifest.img, manifest.img),
    );
    // Stage 0: conv3x3(16) s1 + relu — modeled as its conv (relu cost is
    // negligible and the stage is the atomic placement unit).
    let s0 = m.add(
        LayerKind::Conv2d {
            out_ch: 16,
            kernel: 3,
            stride: 1,
            padding: 1,
        },
        &[input],
    );
    let s1 = m.add(
        LayerKind::Conv2d {
            out_ch: 32,
            kernel: 3,
            stride: 2,
            padding: 1,
        },
        &[s0],
    );
    let f = m.add(LayerKind::Flatten, &[s1]);
    let s2 = m.add(LayerKind::Dense { out_features: 64 }, &[f]);
    m.add(
        LayerKind::Dense {
            out_features: manifest.num_classes,
        },
        &[s2],
    );
    m
}

/// Stage-level cost graph: 5 vertices (input + 4 stages) in a chain.
/// Vertex v>0 aggregates the analytics of stage v-1.
pub fn stage_cost_graph(
    manifest: &Manifest,
    device: &DeviceProfile,
    server: &DeviceProfile,
    cfg: &TrainCfg,
) -> CostGraph {
    let model = l2_model(manifest);
    let full = CostGraph::build(&model, device, server, cfg);
    // Collapse {flatten,dense64} into stage 2; map layers to stages.
    // Model layout: 0 input, 1 conv16, 2 conv32, 3 flatten, 4 dense64,
    // 5 dense10.
    let stage_of = [0usize, 1, 2, 3, 3, 4]; // vertex -> chain position
    let n = 5;
    let mut dag = Dag::new();
    for i in 0..n {
        dag.add_node(if i == 0 {
            "input".to_string()
        } else {
            format!("stage{}", i - 1)
        });
    }
    for i in 1..n {
        dag.add_edge(i - 1, i, 0.0);
    }
    let mut xi_d = vec![0.0; n];
    let mut xi_s = vec![0.0; n];
    let mut act = vec![0.0; n];
    let mut par = vec![0.0; n];
    for v in 0..full.len() {
        let s = stage_of[v];
        xi_d[s] += full.xi_d[v];
        xi_s[s] += full.xi_s[v];
        par[s] += full.param_bytes[v];
        act[s] = full.act_bytes[v]; // last layer of the stage wins
    }
    CostGraph {
        dag,
        xi_d,
        xi_s,
        act_bytes: act,
        param_bytes: par,
        n_loc: cfg.n_loc as f64,
    }
}

/// Map a stage-chain partition to an artifact cut index: the number of
/// *stages* on the device, i.e. [`Partition::cut_layer`] minus the input
/// vertex (vertex 0, pinned to the device). Feasible device sets on the
/// chain are exactly the prefixes, so a non-prefix here is a solver bug.
pub fn partition_to_cut(p: &Partition) -> usize {
    p.cut_layer()
        .expect("stage-chain partition must be a contiguous prefix")
        .saturating_sub(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partition::{blockwise_partition, Link, Problem};

    fn manifest_or_skip() -> Option<Manifest> {
        if !crate::runtime::artifacts_available(crate::runtime::DEFAULT_ARTIFACTS_DIR) {
            eprintln!("skipping: run `make artifacts`");
            return None;
        }
        Some(Manifest::load(crate::runtime::DEFAULT_ARTIFACTS_DIR).unwrap())
    }

    #[test]
    fn stage_graph_is_a_chain_with_manifest_shapes() {
        let Some(m) = manifest_or_skip() else { return };
        let cg = stage_cost_graph(
            &m,
            &DeviceProfile::jetson_tx1(),
            &DeviceProfile::rtx_a6000(),
            &TrainCfg {
                batch: m.batch,
                n_loc: 5,
                bwd_ratio: 2.0,
            },
        );
        assert_eq!(cg.len(), 5);
        assert!(cg.satisfies_assumption1());
        // Activation sizes at the cut points must match the manifest's
        // smashed shapes (x4 bytes).
        let smash1: usize = m.artifacts["srv_step_cut1"].inputs[0].numel();
        assert_eq!(cg.act_bytes[1], (smash1 * 4) as f64);
        let smash3: usize = m.artifacts["srv_step_cut3"].inputs[0].numel();
        assert_eq!(cg.act_bytes[3], (smash3 * 4) as f64);
    }

    #[test]
    fn cut_mapping_spans_all_options() {
        let Some(m) = manifest_or_skip() else { return };
        let cg = stage_cost_graph(
            &m,
            &DeviceProfile::jetson_tx1(),
            &DeviceProfile::rtx_a6000(),
            &TrainCfg::default(),
        );
        // Fast link => central (cut 0); slow-but-free compute device =>
        // larger cuts. Just verify the mapping is consistent & feasible.
        for rate in [1e3, 1e5, 1e7, 1e9, 1e12] {
            let p = Problem::new(&cg, Link::symmetric(rate));
            let part = blockwise_partition(&p);
            let cut = partition_to_cut(&part);
            assert!(cut <= 4);
            // The chain's device set is a prefix including the pinned input.
            assert_eq!(part.cut_layer(), Some(cut + 1));
        }
    }
}
