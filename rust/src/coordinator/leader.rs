//! The leader event loop: per-epoch link collection → ms-scale partition
//! decision → real split-training iterations via PJRT → Eq. (7) delay
//! accounting in simulated time.

use super::costmodel::{partition_to_cut, stage_cost_graph};
use crate::net::{EdgeNetwork, NetConfig};
use crate::partition::{
    DecisionProvenance, FleetSpec, FleetStats, JointOptions, MultiServerPlanner, PlanRequest,
    PlannerService, Problem, ServiceOptions,
};
use crate::profiles::{DeviceProfile, TrainCfg};
use crate::runtime::data::Synthetic;
use crate::runtime::SplitTrainer;
use crate::sim::DelayBreakdown;
use anyhow::Result;
use std::time::Instant;

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    pub artifacts_dir: String,
    pub net: NetConfig,
    pub train: TrainCfg,
    pub lr: f32,
    pub epochs: usize,
    pub seed: u64,
    /// Shared server capacity in concurrent full-throughput
    /// device-equivalents (see `partition::joint`). The default ∞ keeps
    /// the planner bit-identical to the dedicated fleet engine; a finite
    /// value makes every epoch decision congestion-aware.
    pub server_capacity: f64,
    /// Per-server capacity vector (`partition::assign`). With more than
    /// one entry, epoch decisions route through [`MultiServerPlanner`] —
    /// each device assigned to one server, each server priced as its own
    /// shared-capacity [`crate::partition::JointPlanner`]. Empty or
    /// single-entry (the default) keeps the legacy `server_capacity`
    /// service path.
    pub server_capacities: Vec<f64>,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            artifacts_dir: crate::runtime::DEFAULT_ARTIFACTS_DIR.to_string(),
            net: NetConfig {
                num_devices: 4,
                ..NetConfig::default()
            },
            train: TrainCfg {
                batch: 32,
                n_loc: 4,
                bwd_ratio: 2.0,
            },
            lr: 0.05,
            epochs: 10,
            seed: 7,
            server_capacity: f64::INFINITY,
            server_capacities: Vec::new(),
        }
    }
}

/// Per-epoch report combining real numerics with simulated delay.
#[derive(Clone, Debug)]
pub struct EpochReport {
    pub epoch: usize,
    pub device: usize,
    pub device_tier: &'static str,
    /// Chosen artifact cut (0 = central, 4 = device-only).
    pub cut: usize,
    /// Mean training loss over the epoch's local iterations (real numerics).
    pub mean_loss: f64,
    /// Held-out batch accuracy after the epoch (real numerics).
    pub accuracy: f64,
    /// Eq. (7) simulated epoch delay. Under a finite `server_capacity`
    /// this is the load-dependent shared-server delay (see
    /// `partition::joint`).
    pub sim_delay: f64,
    /// The dedicated Eq. (7) decomposition of the chosen cut; on a
    /// congested finite-capacity epoch its components sum to the cut's
    /// dedicated delay, not to `sim_delay` — the gap is the shared-server
    /// queueing share.
    pub breakdown: DelayBreakdown,
    /// Wall-clock of the partition decision (the paper's Table I metric).
    /// This is the fleet facade's actual per-epoch cost: a refresh + solve
    /// when the tier's link changed, a cache fan-out when it did not —
    /// `decision_refreshed` says which one was measured.
    pub decision_time: f64,
    /// True iff the decision ran a fresh solve (false only when the facade
    /// served the tier's bit-identical cached decision).
    pub decision_refreshed: bool,
    /// Where the decision came from (`Fresh`/`Cached` in this fault-free
    /// loop — every report is current-tick, so the service's degraded-mode
    /// policy never triggers; see `partition::service`).
    pub provenance: DecisionProvenance,
    /// Real bytes that crossed the simulated wire this epoch.
    pub wire_bytes: u64,
    /// Real wall-clock of the epoch's PJRT execution.
    pub wall_time: f64,
}

/// The leader: owns the runtime, the network simulator, and the fleet.
pub struct Coordinator {
    cfg: CoordinatorConfig,
    trainer: SplitTrainer,
    net: EdgeNetwork,
    fleet: Vec<DeviceProfile>,
    /// The planning service: the churn-tolerant epoch loop over the joint
    /// facade (per-tier stage cost graphs and transformed networks,
    /// deduplicated and built once — the model and the training config are
    /// fixed for the run). The leader reports every device's sampled link
    /// at the epoch tick and plans the epoch in one
    /// [`PlannerService::plan_epoch`] call — with the default infinite
    /// `server_capacity` the underlying plan is bit-identical to the plain
    /// fleet engine; a finite capacity makes it congestion-aware. The
    /// strict staleness bound (0) means any device whose report ever goes
    /// missing would be served its last-good decision marked `Degraded`
    /// instead of crashing the loop.
    service: PlannerService,
    /// The device→server assignment planner behind a multi-entry
    /// `server_capacities` vector (`partition::assign`); `None` on the
    /// legacy single-server path.
    multi: Option<MultiServerPlanner>,
    data: Synthetic,
    eval_batch: crate::runtime::data::Batch,
    sim_time: f64,
    epoch: usize,
}

impl Coordinator {
    pub fn new(cfg: CoordinatorConfig) -> Result<Coordinator> {
        let trainer = SplitTrainer::new(&cfg.artifacts_dir)?;
        let m = trainer.manifest();
        let mut data = Synthetic::new(m.img, m.channels, m.num_classes, m.batch, cfg.seed);
        let eval_batch = data.next_batch();
        let fleet = DeviceProfile::fleet_of(cfg.net.num_devices);
        let server = DeviceProfile::rtx_a6000();
        let spec = FleetSpec::from_fleet(&fleet, |d| {
            stage_cost_graph(trainer.manifest(), d, &server, &cfg.train)
        });
        let multi = (cfg.server_capacities.len() > 1).then(|| {
            MultiServerPlanner::with_capacities(spec.clone(), cfg.server_capacities.clone())
        });
        let service = PlannerService::new(
            spec,
            ServiceOptions {
                staleness_bound: 0,
                solve_budget: u64::MAX,
                joint: JointOptions::with_capacity(cfg.server_capacity),
            },
        );
        let net = EdgeNetwork::new(cfg.net.clone());
        Ok(Coordinator {
            cfg,
            trainer,
            net,
            fleet,
            service,
            multi,
            data,
            eval_batch,
            sim_time: 0.0,
            epoch: 0,
        })
    }

    pub fn sim_time(&self) -> f64 {
        self.sim_time
    }

    /// The device fleet (for reporting; mirrors [`crate::sim::Trainer::fleet`]).
    pub fn fleet(&self) -> &[DeviceProfile] {
        &self.fleet
    }

    /// Solver counters of the joint planning facade: decision provenance
    /// (refresh/solve counts, reduced-vs-full solve DAG sizes — the stage
    /// graph is a chain, so here `reduced == full` and every decision is an
    /// O(L) scan — plus the shared-capacity price-loop counters; mirrors
    /// [`crate::sim::Trainer::planner_stats`]). On the multi-server path
    /// this is the assignment planner's folded per-server counters.
    pub fn planner_stats(&self) -> FleetStats {
        if let Some(m) = &self.multi {
            return m.stats();
        }
        self.service.stats()
    }

    /// The planner's Prometheus scrape (the [`crate::daemon::metrics`]
    /// service families), ready for a metrics endpoint or a log dump.
    pub fn render_prometheus(&self) -> String {
        crate::daemon::metrics::render_prometheus(&crate::daemon::metrics::service_metrics(
            &self.service,
        ))
    }

    /// Run one epoch of the Sec. III-A loop.
    pub fn run_epoch(&mut self) -> Result<EpochReport> {
        let epoch = self.epoch;
        self.epoch += 1;

        // 1. Collect network + device information: every device's current
        // link is sampled and reported to the planning service at the
        // epoch tick (channel simulation, so it stays outside the timed
        // region below). All reports are current-tick, so nothing is stale
        // and the service plans everyone fresh — under a finite server
        // capacity that is the coupled whole-fleet batch (the server
        // contention only exists fleet-wide); with the default ∞ capacity
        // each tier is a warm refresh + solve, bit-identical to the plain
        // fleet engine.
        let device = self.net.select_device(self.sim_time);
        let tier = self.service.spec().tier_of(device);
        let tier_name = self.service.spec().tier_name(tier);
        let num_devices = self.service.spec().num_devices();
        let mut links = Vec::with_capacity(num_devices);
        for d in 0..num_devices {
            let l = self.net.sample_link(d, self.sim_time).to_link();
            links.push(l);
            if self.multi.is_none() {
                self.service.report(d, l, epoch as u64);
            }
        }
        let link = links[device];
        // On the multi-server path the epoch batch goes to the assignment
        // planner directly, so the requests are built here (channel
        // bookkeeping) instead of reported to the service inbox.
        let multi_requests: Option<Vec<PlanRequest>> = self.multi.is_some().then(|| {
            (0..num_devices)
                .map(|d| PlanRequest {
                    device: d,
                    tier: self.service.spec().tier_of(d),
                    link: links[d],
                })
                .collect()
        });

        // 2. Decide the partition through the service's epoch loop — or,
        // with a multi-entry capacity vector, through the device→server
        // assignment planner. The timed region is exactly the per-epoch
        // decision work (capacity refresh + warm solve per dirty tier,
        // plus the price loop when congested; plus the assignment search
        // on the multi-server path) — the paper's Table I decision metric.
        let t0 = Instant::now();
        let decision = if let Some(requests) = &multi_requests {
            self.multi
                .as_mut()
                .expect("requests only built on the multi-server path")
                .plan(requests)
                .into_iter()
                .find(|d| d.device == device)
                .expect("one decision per device")
        } else {
            self.service
                .plan_epoch(epoch as u64)
                .expect("the coordinator's epoch clock is monotone")
                .into_iter()
                .find(|d| d.device == device)
                .expect("one decision per device")
        };
        let decision_time = t0.elapsed().as_secs_f64();
        let decision_refreshed = decision.stats.refreshed;
        let provenance = decision.provenance;
        let partition = decision.partition;
        let cut = partition_to_cut(&partition);
        let problem = Problem::new(self.service.spec().tier_costs(tier), link);
        let breakdown = DelayBreakdown::of(&problem, &partition.device_set);

        // 3. Execute N_loc real local iterations at the chosen cut.
        let wall0 = Instant::now();
        let mut loss_sum = 0.0;
        let mut wire_bytes = 0u64;
        for _ in 0..self.cfg.train.n_loc {
            let batch = self.data.next_batch();
            let out = self.trainer.step(cut, &batch, self.cfg.lr)?;
            loss_sum += out.loss as f64;
            wire_bytes += out.wire_bytes;
        }
        let accuracy = self.trainer.accuracy(&self.eval_batch)?;
        let wall_time = wall0.elapsed().as_secs_f64();

        // 4. Advance simulated time by the Eq. (7) epoch delay.
        self.sim_time += partition.delay + decision_time;

        Ok(EpochReport {
            epoch,
            device,
            device_tier: tier_name,
            cut,
            mean_loss: loss_sum / self.cfg.train.n_loc as f64,
            accuracy,
            sim_delay: partition.delay,
            breakdown,
            decision_time,
            decision_refreshed,
            provenance,
            wire_bytes,
            wall_time,
        })
    }

    /// Run the configured number of epochs, returning all reports.
    pub fn run(&mut self) -> Result<Vec<EpochReport>> {
        (0..self.cfg.epochs).map(|_| self.run_epoch()).collect()
    }
}
