//! L3 leader coordinator: the paper's training process (Sec. III-A) with
//! *real* numerics.
//!
//! Per epoch the leader collects the selected device's link state from the
//! network simulator, runs the block-wise partitioning algorithm on the L2
//! model's cost graph (millisecond decision, as in Table I), maps the
//! optimal cut onto the compiled artifacts, and drives `N_loc` real
//! split-training iterations through PJRT on a worker thread while
//! accounting simulated wall-clock per Eq. (7).

pub mod costmodel;
pub mod leader;

pub use leader::{Coordinator, CoordinatorConfig, EpochReport};
