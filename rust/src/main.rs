//! `fastsplit` — CLI for the split-learning partitioning framework.
//!
//! Subcommands:
//!   info       <--model NAME>            per-layer model inventory
//!   partition  <--model --up --down ...> one partition decision
//!   simulate   <--model --method ...>    SL delay simulation over epochs
//!   experiment <--id fig7a|...|all>      regenerate a paper table/figure
//!   train      <--epochs ...>            real split training via PJRT
//!   models                               list zoo models

use fastsplit::coordinator::{Coordinator, CoordinatorConfig};
use fastsplit::models;
use fastsplit::net::{Band, ChannelCondition, NetConfig};
use fastsplit::partition::baselines::partition_by_method;
use fastsplit::partition::{Link, Problem};
use fastsplit::profiles::{CostGraph, DeviceProfile, TrainCfg};
use fastsplit::sim::{SimConfig, Trainer};
use fastsplit::util::cli::Args;
use fastsplit::util::{fmt_bytes, fmt_secs};

const USAGE: &str = "\
fastsplit — fast AI model partitioning for split learning (paper reproduction)

USAGE:
  fastsplit models
  fastsplit info --model resnet18
  fastsplit partition --model googlenet --method proposed --up-mbps 20 --down-mbps 80 \\
                      --device jetson-tx2 [--n-loc 10] [--batch 32]
  fastsplit simulate --model googlenet --method proposed --band mmwave \\
                      --condition normal [--epochs 50] [--devices 20] [--rayleigh] [--seed 7] \\
                      [--server-capacity 0.4] [--path-hops 3] [--server-capacities 0.4,0.4] \\
                      [--metrics] [--journal-dir DIR]
  fastsplit experiment --id fig7a|fig7b|fig8|fig9a|fig9b|tab1|fig11|fig12|fig13|tab2|fig14|fig15|fig16|ablA|ablB|topoA|topoB|all [--quick]
  fastsplit train [--epochs 10] [--n-loc 4] [--lr 0.05] [--artifacts artifacts] [--devices 4] \\
                      [--server-capacities 0.4,0.4]
";

fn main() {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        print!("{USAGE}");
        std::process::exit(2);
    }
    let cmd = argv.remove(0);
    let args = Args::parse(argv, &["quick", "rayleigh", "verbose", "metrics"]);
    let result = match cmd.as_str() {
        "models" => cmd_models(),
        "info" => cmd_info(&args),
        "partition" => cmd_partition(&args),
        "simulate" => cmd_simulate(&args),
        "experiment" => cmd_experiment(&args),
        "train" => cmd_train(&args),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => {
            eprintln!("unknown command '{other}'\n{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn cmd_models() -> anyhow::Result<()> {
    println!("available models:");
    for name in models::MODEL_NAMES {
        let m = models::by_name(name).unwrap();
        println!(
            "  {name:<16} {:>4} layers  {:>8.2} GFLOPs  {:>7.1}M params  mean act {}",
            m.len(),
            m.total_flops() as f64 / 1e9,
            m.total_params() as f64 / 1e6,
            fmt_bytes(m.mean_act_bytes()),
        );
    }
    Ok(())
}

fn cmd_info(args: &Args) -> anyhow::Result<()> {
    let name = args.get_or("model", "resnet18");
    let m = models::by_name(name).ok_or_else(|| anyhow::anyhow!("unknown model '{name}'"))?;
    println!("{}", m.describe());
    println!(
        "total: {} layers, {:.2} GFLOPs, {:.1}M params, linear={}, declared blocks={}",
        m.len(),
        m.total_flops() as f64 / 1e9,
        m.total_params() as f64 / 1e6,
        m.is_linear(),
        m.declared_blocks().len(),
    );
    Ok(())
}

fn device_by_name(name: &str) -> anyhow::Result<DeviceProfile> {
    Ok(match name {
        "jetson-tx1" => DeviceProfile::jetson_tx1(),
        "jetson-tx2" => DeviceProfile::jetson_tx2(),
        "jetson-orin-nano" => DeviceProfile::jetson_orin_nano(),
        "jetson-agx-orin" => DeviceProfile::jetson_agx_orin(),
        "rtx-a6000" => DeviceProfile::rtx_a6000(),
        other => anyhow::bail!("unknown device '{other}'"),
    })
}

fn cmd_partition(args: &Args) -> anyhow::Result<()> {
    let model_name = args.get_or("model", "googlenet");
    let model = models::by_name(model_name)
        .ok_or_else(|| anyhow::anyhow!("unknown model '{model_name}'"))?;
    let device = device_by_name(args.get_or("device", "jetson-tx2"))?;
    let cfg = TrainCfg {
        batch: args.get_usize("batch", 32),
        n_loc: args.get_usize("n-loc", 10) as u32,
        bwd_ratio: 2.0,
    };
    let costs = CostGraph::build(&model, &device, &DeviceProfile::rtx_a6000(), &cfg);
    let link = Link {
        up_bps: args.get_f64("up-mbps", 20.0) * 1e6 / 8.0,
        down_bps: args.get_f64("down-mbps", 80.0) * 1e6 / 8.0,
    };
    let p = Problem::new(&costs, link);
    let method = args.get_or("method", "proposed");
    let t0 = std::time::Instant::now();
    let part = partition_by_method(method, &p, link);
    let took = t0.elapsed().as_secs_f64();
    println!(
        "model={model_name} method={method} device={} decision={}",
        device.name,
        fmt_secs(took)
    );
    println!("  {}", part.describe());
    let b = fastsplit::sim::DelayBreakdown::of(&p, &part.device_set);
    println!(
        "  breakdown: device {} | server {} | activations {} | model-xfer {}",
        fmt_secs(b.device_compute),
        fmt_secs(b.server_compute),
        fmt_secs(b.activation_transfer),
        fmt_secs(b.model_transfer),
    );
    Ok(())
}

/// Parse a comma-separated `--server-capacities` list (e.g. `0.4,0.4`)
/// into the per-server capacity vector of `partition::assign`.
fn parse_capacities(arg: Option<&str>) -> anyhow::Result<Vec<f64>> {
    match arg {
        None => Ok(Vec::new()),
        Some(s) => s
            .split(',')
            .map(|x| {
                x.trim()
                    .parse::<f64>()
                    .map_err(|e| anyhow::anyhow!("bad --server-capacities entry '{x}': {e}"))
            })
            .collect(),
    }
}

fn cmd_simulate(args: &Args) -> anyhow::Result<()> {
    let band = Band::by_name(args.get_or("band", "mmwave"))
        .ok_or_else(|| anyhow::anyhow!("unknown band"))?;
    let condition = match args.get_or("condition", "normal") {
        "good" => ChannelCondition::Good,
        "normal" => ChannelCondition::Normal,
        "poor" => ChannelCondition::Poor,
        other => anyhow::bail!("unknown condition '{other}'"),
    };
    let cfg = SimConfig {
        model: args.get_or("model", "googlenet").to_string(),
        net: NetConfig {
            band,
            condition,
            rayleigh: args.flag("rayleigh"),
            num_devices: args.get_usize("devices", 20),
            ..NetConfig::default()
        },
        method: args.get_or("method", "proposed").to_string(),
        seed: args.get_u64("seed", 7),
        server_capacity: args.get_f64("server-capacity", f64::INFINITY),
        path_hops: args.get_usize("path-hops", 1),
        server_capacities: parse_capacities(args.get("server-capacities"))?,
        ..SimConfig::default()
    };
    let epochs = args.get_usize("epochs", 50);
    let mut trainer = Trainer::new(cfg);
    let res = trainer.run_epochs(epochs);
    println!(
        "{} epochs: total {} | mean/epoch {} | mean decision {}",
        epochs,
        fmt_secs(res.total_delay),
        fmt_secs(res.mean_epoch_delay),
        fmt_secs(res.mean_decision_time),
    );
    if args.flag("verbose") {
        for r in &res.records {
            println!(
                "  epoch {:>4} dev {:>2} ({:<16}) cut-layers {:>3} delay {}",
                r.epoch,
                r.device,
                r.device_tier,
                r.device_layers,
                fmt_secs(r.delay)
            );
        }
    }
    if args.flag("metrics") {
        // The planner's Prometheus scrape after the run — the same text a
        // daemon metrics endpoint would serve.
        print!("{}", trainer.render_prometheus());
    }
    if let Some(dir) = args.get("journal-dir") {
        simulate_journaled(
            args.get_or("model", "googlenet"),
            args.get_usize("devices", 20),
            epochs,
            args.get_u64("seed", 7),
            dir,
        )?;
    }
    Ok(())
}

/// PR 9 demo lane (`--journal-dir`): mirror the simulation's epoch loop
/// through a write-ahead-journaled planner daemon, crash it without a
/// drain, recover from disk, and verify the recovered scrape is
/// bit-identical to the pre-crash daemon (journal counters excluded).
/// Exits non-zero on any divergence, so CI can drive it directly.
fn simulate_journaled(
    model: &str,
    num_devices: usize,
    epochs: usize,
    seed: u64,
    dir: &str,
) -> anyhow::Result<()> {
    use fastsplit::daemon::{DaemonConfig, DaemonEvent, PlannerDaemon, SimClock};
    use fastsplit::net::EdgeNetwork;
    use fastsplit::partition::FleetSpec;
    use std::sync::Arc;

    let m = models::by_name(model).ok_or_else(|| anyhow::anyhow!("unknown model '{model}'"))?;
    let server = DeviceProfile::rtx_a6000();
    let fleet = DeviceProfile::fleet_of(num_devices);
    let spec = FleetSpec::from_fleet(&fleet, |d| {
        CostGraph::build(&m, d, &server, &TrainCfg::default())
    });
    let fingerprint = spec.fingerprint();
    let mut net = EdgeNetwork::new(NetConfig {
        rayleigh: true,
        num_devices,
        seed,
        ..NetConfig::default()
    });

    println!("\njournaled daemon mirror ({model}, {num_devices} devices): {epochs} ticks -> {dir}");
    let clock = SimClock::new(0);
    let daemon = PlannerDaemon::spawn(
        spec,
        DaemonConfig {
            replan_every: 1,
            journal_dir: Some(dir.into()),
            ..DaemonConfig::default()
        },
        Arc::new(clock.clone()),
    );
    let mut planned = 0usize;
    for tick in 1..=epochs as u64 {
        clock.set(tick);
        for d in 0..num_devices {
            let link = net.sample_link(d, tick as f64).to_link();
            let _ = daemon.send(DaemonEvent::Report {
                device: d,
                link,
                tick,
            });
        }
        planned += daemon.pump().epochs.len();
    }
    let pre_metrics = daemon.metrics();
    daemon.abandon(); // the injected crash: no drain frame reaches the journal
    println!("  {planned} epochs planned, then crashed without a drain");

    let (recovered, report) =
        PlannerDaemon::recover_expecting(dir, fingerprint, Arc::new(SimClock::new(epochs as u64)))
            .map_err(|e| anyhow::anyhow!("recovery failed: {e}"))?;
    println!(
        "  recovered from snapshot at tick {}: {} frames replayed ({} events), \
         torn {}, shutdown {:?}, {} newer files skipped",
        report.snapshot_tick,
        report.replayed_frames,
        report.replayed_events,
        report.torn_frames,
        report.shutdown,
        report.files_skipped,
    );
    // Journal counters differ by construction (the recovered daemon wrote
    // fewer frames and counts the recovery); everything else must match.
    let stable = |scrape: &str| -> String {
        scrape
            .lines()
            .filter(|l| !l.contains("fastsplit_journal_") && !l.contains("fastsplit_ingest_shed"))
            .collect::<Vec<_>>()
            .join("\n")
    };
    let post_metrics = recovered.metrics();
    if stable(&pre_metrics) != stable(&post_metrics) {
        anyhow::bail!("recovered scrape diverged from the pre-crash daemon");
    }
    println!("  scrape match: bit-identical (journal counters excluded)");
    recovered.shutdown();
    Ok(())
}

fn cmd_experiment(args: &Args) -> anyhow::Result<()> {
    let id = args.get_or("id", "all");
    let quick = args.flag("quick");
    let ids: Vec<&str> = if id == "all" {
        fastsplit::experiments::ALL_IDS.to_vec()
    } else {
        vec![id]
    };
    for id in ids {
        let out = fastsplit::experiments::run(id, quick)
            .ok_or_else(|| anyhow::anyhow!("unknown experiment '{id}'"))?;
        println!("=== {id} ===\n{out}");
    }
    Ok(())
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    let cfg = CoordinatorConfig {
        artifacts_dir: args.get_or("artifacts", "artifacts").to_string(),
        net: NetConfig {
            num_devices: args.get_usize("devices", 4),
            ..NetConfig::default()
        },
        train: TrainCfg {
            batch: 32,
            n_loc: args.get_usize("n-loc", 4) as u32,
            bwd_ratio: 2.0,
        },
        lr: args.get_f64("lr", 0.05) as f32,
        epochs: args.get_usize("epochs", 10),
        seed: args.get_u64("seed", 7),
        server_capacity: args.get_f64("server-capacity", f64::INFINITY),
        server_capacities: parse_capacities(args.get("server-capacities"))?,
    };
    let mut coord = Coordinator::new(cfg.clone())?;
    println!(
        "split training: {} epochs x {} local iterations (real numerics via PJRT)",
        cfg.epochs, cfg.train.n_loc
    );
    for _ in 0..cfg.epochs {
        let r = coord.run_epoch()?;
        println!(
            "epoch {:>3} dev {:>2} ({:<16}) cut {} loss {:.4} acc {:>5.1}% sim-delay {} wire {} decision {} wall {}",
            r.epoch,
            r.device,
            r.device_tier,
            r.cut,
            r.mean_loss,
            r.accuracy * 100.0,
            fmt_secs(r.sim_delay),
            fmt_bytes(r.wire_bytes as f64),
            fmt_secs(r.decision_time),
            fmt_secs(r.wall_time),
        );
    }
    println!("total simulated time: {}", fmt_secs(coord.sim_time()));
    Ok(())
}
