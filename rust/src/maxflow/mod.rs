//! Maximum-flow / minimum s-t cut solvers.
//!
//! The paper (Sec. V-A, VI-D) uses Dinic's algorithm; [`dinic`] is the
//! production solver and [`push_relabel`] (FIFO push-relabel with the gap
//! heuristic) is an independent implementation used for cross-checking and
//! the solver ablation bench. Both operate on [`FlowNetwork`] — a frozen
//! CSR residual network with `f64` capacities (delays in seconds) and
//! `f64::INFINITY` support for the precedence-enforcing edges.
//!
//! Hot-path reuse: [`dinic_with`] takes caller-owned [`DinicScratch`]
//! buffers, and `FlowNetwork::set_edge_capacity` re-capacitates edges
//! without touching topology, so a network can be re-solved every epoch
//! with zero allocation (see `partition::planner`). On top of that,
//! [`incremental`] carries the previous solve's **flow** across a
//! capacity refresh (`FlowNetwork::update_edge_capacity` +
//! `IncrementalScratch::resolve`): violated arcs are repaired by bounded
//! cancel-DFS passes and Dinic only augments the repaired residual — the
//! GGT-style warm re-solve the fleet planner runs when only the link's
//! σ = 1/R_up + 1/R_down changed between epochs.

pub mod network;
pub mod dinic;
pub mod incremental;
pub mod push_relabel;

pub use dinic::{dinic, dinic_augment, dinic_with, DinicScratch};
pub use incremental::{IncrementalScratch, ResolveStats};
pub use network::{FlowNetwork, MinCut};
pub use push_relabel::push_relabel;
