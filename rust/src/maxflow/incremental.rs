//! Incremental (flow-reusing) max-flow re-solves — Gallo–Grigoriadis–
//! Tarjan-style warm starts for the per-epoch re-partitioning loop.
//!
//! The planner's transformed networks change only in *capacities* between
//! epochs (every capacity is affine in the link's round-trip byte cost
//! σ — see `partition::fleet`), so consecutive solves are solves of
//! closely-related networks. The PR-1 warm path already reuses the
//! topology (frozen CSR + O(E) capacity refresh) but discards the flow
//! and re-runs Dinic from zero. This module carries the **flow** across
//! the refresh as well:
//!
//! 1. [`FlowNetwork::update_edge_capacity`] rewrites each capacity while
//!    keeping `min(flow, new_cap)` units routed, reporting the amount by
//!    which the carried flow overshoots the new capacity (the *violation*).
//! 2. [`IncrementalScratch::resolve`] repairs flow conservation: every
//!    violated edge `(u, v)` with overshoot δ leaves `u` with δ excess
//!    inflow and `v` with δ missing inflow. Excess drains **backwards**
//!    along flow-carrying arcs into the source or into a deficit vertex;
//!    remaining deficits drain **forwards** along flow-carrying arcs into
//!    the sink (both exist by flow decomposition: the clamped flow plus
//!    the removed δ·(u,v) units decompose into s-t paths and cycles, whose
//!    fragments end exactly at those terminals). Each cancellation is a
//!    bounded DFS over arcs that still carry flow.
//! 3. The repaired flow is feasible, so [`dinic_augment`] completes it to
//!    a maximum flow from the residual — on small σ drifts this is zero or
//!    one BFS phase instead of a from-scratch Dinic run. When σ *grows*
//!    (rates fading), capacities only increase, no repair is needed at
//!    all, and the resolve is the classic monotone GGT case.
//!
//! The resulting min cut has the same **value** as a cold solve (max-flow
//! is max-flow) but may be a different *co-optimal* cut: the residual
//! reachability of a different maximum flow. Callers that promise
//! bit-identity must keep using the cold path (`set_edge_capacity` +
//! `dinic_with`); the fleet engine pins the incremental path with the
//! cut-cost equivalence harness instead (`util::prop::assert_cut_cost_equal`).
//!
//! Robustness: [`IncrementalScratch::resolve`] returns `None` if a repair
//! DFS ever fails to find a cancel path (which the decomposition argument
//! rules out up to floating-point pathology) — callers fall back to a cold
//! refresh + solve, so correctness never rests on the repair pass.

use super::dinic::{dinic_augment, DinicScratch};
use super::network::{FlowNetwork, MinCut, EPS};

/// Counters from one incremental resolve (surfaced by `FleetStats`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ResolveStats {
    /// Arc cancellations performed by the conservation-repair passes.
    pub repair_pushes: u64,
    /// BFS phases the post-repair Dinic augmentation ran.
    pub augment_rounds: u64,
    /// Forward edges whose refreshed capacity undercut their carried flow.
    pub violated_edges: u64,
}

/// Reusable state of the incremental re-solver: the violation list filled
/// between [`IncrementalScratch::begin`] and [`IncrementalScratch::resolve`]
/// plus the repair passes' scratch buffers, so a warm re-solve allocates
/// nothing after the first call.
#[derive(Default)]
pub struct IncrementalScratch {
    /// (edge id, overshoot) pairs recorded during the capacity refresh.
    violations: Vec<(u32, f64)>,
    /// Net excess inflow per vertex (positive entries need draining).
    excess: Vec<f64>,
    /// Net missing inflow per vertex.
    deficit: Vec<f64>,
    excess_verts: Vec<u32>,
    deficit_verts: Vec<u32>,
    /// DFS visit stamps (per-search epoch marking, never cleared).
    visited: Vec<u32>,
    stamp: u32,
    /// DFS frames: (vertex, next CSR position to scan).
    frames: Vec<(u32, u32)>,
    /// Cancel arcs (always odd twins) of the current DFS path.
    path: Vec<u32>,
}

impl IncrementalScratch {
    /// Start recording capacity violations for a new refresh pass.
    pub fn begin(&mut self) {
        self.violations.clear();
    }

    /// Record that forward edge `edge`'s refresh left `amount` units of
    /// carried flow above its new capacity (the return value of
    /// [`FlowNetwork::update_edge_capacity`]; ~0 amounts are ignored).
    pub fn record(&mut self, edge: usize, amount: f64) {
        if amount > EPS {
            self.violations.push((edge as u32, amount));
        }
    }

    /// Edges recorded as violated since the last [`IncrementalScratch::begin`].
    pub fn violations(&self) -> usize {
        self.violations.len()
    }

    /// Repair the carried flow's conservation at every recorded violation,
    /// then augment the repaired residual to a maximum flow. Returns the
    /// min cut (value read back from the source's net outflow) and the
    /// repair/augment counters, or `None` if a repair DFS dead-ends —
    /// callers must then fall back to a cold refresh + solve.
    pub fn resolve(
        &mut self,
        net: &mut FlowNetwork,
        s: usize,
        t: usize,
        scratch: &mut DinicScratch,
    ) -> Option<(MinCut, ResolveStats)> {
        net.freeze();
        let n = net.len();
        let mut stats = ResolveStats {
            violated_edges: self.violations.len() as u64,
            ..ResolveStats::default()
        };

        // Net per-vertex imbalance of the clamped flow. Excess at the
        // source or deficit at the sink is just a smaller flow value, not
        // a conservation break — only interior vertices need repair.
        self.excess.clear();
        self.excess.resize(n, 0.0);
        self.deficit.clear();
        self.deficit.resize(n, 0.0);
        self.excess_verts.clear();
        self.deficit_verts.clear();
        let violations = std::mem::take(&mut self.violations);
        for &(e, amount) in &violations {
            let (u, v) = net.edge_endpoints(e as usize);
            if u != s && u != t {
                if self.excess[u] == 0.0 {
                    self.excess_verts.push(u as u32);
                }
                self.excess[u] += amount;
            }
            if v != s && v != t {
                if self.deficit[v] == 0.0 {
                    self.deficit_verts.push(v as u32);
                }
                self.deficit[v] += amount;
            }
        }
        self.violations = violations;
        // A vertex hit by violations on both sides carries only its *net*
        // imbalance (conservation is a net property); cancel the overlap
        // locally so the passes below see disjoint excess/deficit sets.
        let excess_verts = std::mem::take(&mut self.excess_verts);
        let deficit_verts = std::mem::take(&mut self.deficit_verts);
        for &x in &excess_verts {
            let x = x as usize;
            let overlap = self.excess[x].min(self.deficit[x]);
            if overlap > 0.0 {
                self.excess[x] -= overlap;
                self.deficit[x] -= overlap;
            }
        }

        // Pass 1 — drain every interior excess backwards along
        // flow-carrying arcs into the source (reducing the flow value) or
        // into a deficit vertex (net rebalance, value unchanged).
        let mut repaired = true;
        'excess: for &u in &excess_verts {
            let u = u as usize;
            while self.excess[u] > EPS {
                let Some(target) = self.find_cancel_path(net, u, s, t, true) else {
                    repaired = false;
                    break 'excess;
                };
                let mut amt = self.excess[u];
                for &arc in &self.path {
                    amt = amt.min(net.arc_cap(arc as usize));
                }
                if target != s {
                    amt = amt.min(self.deficit[target]);
                }
                if amt <= EPS {
                    repaired = false; // numerical dead end: fall back to cold
                    break 'excess;
                }
                for &arc in &self.path {
                    net.push_on(arc as usize, amt);
                }
                stats.repair_pushes += self.path.len() as u64;
                self.excess[u] -= amt;
                if target != s {
                    self.deficit[target] -= amt;
                }
            }
        }

        // Pass 2 — drain every remaining deficit forwards along
        // flow-carrying arcs into the sink (reducing the flow value).
        if repaired {
            'deficit: for &v in &deficit_verts {
                let v = v as usize;
                while self.deficit[v] > EPS {
                    if self.find_cancel_path(net, v, s, t, false).is_none() {
                        repaired = false;
                        break 'deficit;
                    }
                    let mut amt = self.deficit[v];
                    for &arc in &self.path {
                        amt = amt.min(net.arc_cap(arc as usize));
                    }
                    if amt <= EPS {
                        repaired = false;
                        break 'deficit;
                    }
                    for &arc in &self.path {
                        net.push_on(arc as usize, amt);
                    }
                    stats.repair_pushes += self.path.len() as u64;
                    self.deficit[v] -= amt;
                }
            }
        }
        self.excess_verts = excess_verts;
        self.deficit_verts = deficit_verts;
        if !repaired {
            return None;
        }

        // The carried flow is feasible again: complete it to a maximum
        // flow from the repaired residual.
        let (_added, phases) = dinic_augment(net, s, t, scratch);
        stats.augment_rounds = phases;
        let source_side = net.residual_source_side(s);
        debug_assert!(!source_side[t], "sink on source side after incremental re-solve");
        let value = net.outflow(s);
        Some((MinCut { value, source_side }, stats))
    }

    /// DFS for one cancelable path of routed flow, left in `self.path` as
    /// cancel arcs (always the odd twin of each traversed edge — pushing
    /// on them reduces the edge's flow), tail first. `backward == true`
    /// searches from an excess vertex *against* the flow direction (odd
    /// twin arcs with positive cap, i.e. edges carrying flow into the
    /// current vertex) and succeeds on reaching the source or any vertex
    /// with outstanding deficit; `backward == false` searches from a
    /// deficit vertex *along* the flow direction and succeeds on reaching
    /// the sink. Returns the terminal vertex.
    ///
    /// The source is never traversed through in the forward pass and the
    /// sink never in the backward pass: conservation does not hold at the
    /// terminals, so flow cannot be traced through them.
    fn find_cancel_path(
        &mut self,
        net: &FlowNetwork,
        start: usize,
        s: usize,
        t: usize,
        backward: bool,
    ) -> Option<usize> {
        let n = net.len();
        self.visited.resize(n, 0);
        if self.stamp == u32::MAX {
            self.visited.fill(0);
            self.stamp = 0;
        }
        self.stamp += 1;
        let stamp = self.stamp;
        self.visited[start] = stamp;
        self.frames.clear();
        self.path.clear();
        self.frames
            .push((start as u32, net.arc_range(start).start as u32));

        'outer: loop {
            let &(v, pos) = self.frames.last()?;
            let v = v as usize;
            let mut pos = pos as usize;
            let end = net.arc_range(v).end;
            while pos < end {
                let arc = net.arc_at(pos);
                pos += 1;
                // An arc is traversable iff its edge still carries flow in
                // the direction of this pass; the cancel arc is the edge's
                // odd twin either way.
                let (ok, cancel_arc) = if backward {
                    (arc & 1 == 1 && net.arc_cap(arc) > EPS, arc)
                } else {
                    (arc & 1 == 0 && net.arc_cap(arc ^ 1) > EPS, arc ^ 1)
                };
                if !ok {
                    continue;
                }
                let w = net.arc_to(arc);
                let done = if backward {
                    w == s || self.deficit[w] > EPS
                } else {
                    w == t
                };
                if done {
                    self.path.push(cancel_arc as u32);
                    return Some(w);
                }
                let blocked = if backward { w == t } else { w == s };
                if !blocked && self.visited[w] != stamp {
                    self.visited[w] = stamp;
                    let last = self.frames.last_mut().expect("frame just read");
                    last.1 = pos as u32;
                    self.frames.push((w as u32, net.arc_range(w).start as u32));
                    self.path.push(cancel_arc as u32);
                    continue 'outer;
                }
            }
            self.frames.pop();
            self.path.pop();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::maxflow::{dinic, dinic_with};
    use crate::util::prop::for_all;

    /// Apply new capacities flow-preservingly, recording violations.
    fn refresh_preserving(net: &mut FlowNetwork, caps: &[f64], inc: &mut IncrementalScratch) {
        inc.begin();
        for (e, &c) in caps.iter().enumerate() {
            let violated = net.update_edge_capacity(e, c);
            inc.record(e, violated);
        }
    }

    fn clrs_edges() -> Vec<(usize, usize, f64)> {
        vec![
            (0, 1, 16.0),
            (0, 2, 13.0),
            (1, 2, 10.0),
            (2, 1, 4.0),
            (1, 3, 12.0),
            (3, 2, 9.0),
            (2, 4, 14.0),
            (4, 3, 7.0),
            (3, 5, 20.0),
            (4, 5, 4.0),
        ]
    }

    fn build(n: usize, edges: &[(usize, usize, f64)]) -> FlowNetwork {
        let mut net = FlowNetwork::new(n);
        for &(u, v, c) in edges {
            net.add_edge(u, v, c);
        }
        net
    }

    /// Incremental re-solve after a capacity change must match a cold
    /// solve of a freshly-built network with the same capacities.
    fn assert_matches_cold(
        net: &mut FlowNetwork,
        n: usize,
        edges: &[(usize, usize, f64)],
        caps: &[f64],
        s: usize,
        t: usize,
    ) -> ResolveStats {
        let mut inc = IncrementalScratch::default();
        let mut scratch = DinicScratch::default();
        refresh_preserving(net, caps, &mut inc);
        let (cut, stats) = inc
            .resolve(net, s, t, &mut scratch)
            .expect("repair pass must succeed on well-formed flows");
        let fresh_edges: Vec<(usize, usize, f64)> = edges
            .iter()
            .zip(caps)
            .map(|(&(u, v, _), &c)| (u, v, c))
            .collect();
        let cold = dinic(&mut build(n, &fresh_edges), s, t);
        assert!(
            (cut.value - cold.value).abs() <= 1e-9 * (1.0 + cold.value.abs()),
            "incremental value {} != cold value {}",
            cut.value,
            cold.value
        );
        // The incremental cut must itself be a cut of value == flow.
        assert!(
            (net.cut_value(&cut.source_side) - cut.value).abs() <= 1e-9 * (1.0 + cut.value.abs()),
            "incremental cut is not tight"
        );
        assert!(!cut.source_side[t]);
        assert!(cut.source_side[s]);
        stats
    }

    #[test]
    fn clrs_capacity_cut_resolves_incrementally() {
        let edges = clrs_edges();
        let mut net = build(6, &edges);
        let first = dinic(&mut net, 0, 5);
        assert!((first.value - 23.0).abs() < 1e-9);
        // Shrink the two source edges below their carried flow: new max
        // flow is 5 + 13 = 18 and both repairs drain straight into s.
        let caps = [5.0, 13.0, 10.0, 4.0, 12.0, 9.0, 14.0, 7.0, 20.0, 4.0];
        let stats = assert_matches_cold(&mut net, 6, &edges, &caps, 0, 5);
        assert!(stats.violated_edges >= 1);
        assert!(stats.repair_pushes >= 1);
    }

    #[test]
    fn pure_capacity_increase_needs_no_repair() {
        let edges = clrs_edges();
        let mut net = build(6, &edges);
        let _ = dinic(&mut net, 0, 5);
        let caps: Vec<f64> = edges.iter().map(|&(_, _, c)| c * 1.5).collect();
        let stats = assert_matches_cold(&mut net, 6, &edges, &caps, 0, 5);
        assert_eq!(stats.violated_edges, 0);
        assert_eq!(stats.repair_pushes, 0);
    }

    #[test]
    fn unchanged_capacities_resolve_with_zero_work() {
        let edges = clrs_edges();
        let mut net = build(6, &edges);
        let mut scratch = DinicScratch::default();
        let first = dinic_with(&mut net, 0, 5, &mut scratch);
        let caps: Vec<f64> = edges.iter().map(|&(_, _, c)| c).collect();
        let mut inc = IncrementalScratch::default();
        refresh_preserving(&mut net, &caps, &mut inc);
        let (cut, stats) = inc.resolve(&mut net, 0, 5, &mut scratch).unwrap();
        assert_eq!(stats.repair_pushes, 0);
        assert_eq!(stats.augment_rounds, 0, "flow already maximal");
        assert!((cut.value - first.value).abs() < 1e-9);
        assert_eq!(cut.source_side, first.source_side);
    }

    #[test]
    fn edge_zeroed_to_nothing_resolves() {
        let edges = clrs_edges();
        let mut net = build(6, &edges);
        let _ = dinic(&mut net, 0, 5);
        // Kill the 3->5 edge entirely: max flow collapses to the 4->5 cap.
        let caps = [16.0, 13.0, 10.0, 4.0, 12.0, 9.0, 14.0, 7.0, 0.0, 4.0];
        let stats = assert_matches_cold(&mut net, 6, &edges, &caps, 0, 5);
        assert!(stats.violated_edges >= 1);
    }

    #[test]
    fn infinite_edges_survive_incremental_refreshes() {
        // s -> a (inf), a -> t (1), s -> t (2): the infinite edge carries
        // flow; refreshing must keep it routed and never violate it.
        let edges = [(0, 1, f64::INFINITY), (1, 2, 1.0), (0, 2, 2.0)];
        let mut net = build(3, &edges);
        let _ = dinic(&mut net, 0, 2);
        let caps = [f64::INFINITY, 3.0, 0.5];
        let stats = assert_matches_cold(&mut net, 3, &edges, &caps, 0, 2);
        assert_eq!(
            stats.violated_edges, 1,
            "only the finite s->t edge can be violated"
        );
    }

    #[test]
    fn random_capacity_walks_match_cold_solves() {
        for_all("incremental-random-walks", 40, |rng| {
            let n = 2 + rng.index(12);
            let mut edges = Vec::new();
            for u in 0..n {
                for v in 0..n {
                    if u != v && rng.chance(0.35) {
                        edges.push((u, v, rng.range(0.0, 10.0)));
                    }
                }
            }
            if edges.is_empty() {
                edges.push((0, n - 1, rng.range(0.0, 10.0)));
            }
            let mut net = build(n, &edges);
            let _ = dinic(&mut net, 0, n - 1);
            // A walk of refreshes: small drifts and occasional hard jumps,
            // each incremental resolve checked against a cold rebuild.
            let mut caps: Vec<f64> = edges.iter().map(|&(_, _, c)| c).collect();
            for _ in 0..6 {
                for c in caps.iter_mut() {
                    *c = if rng.chance(0.2) {
                        rng.range(0.0, 10.0)
                    } else {
                        (*c * rng.range(0.7, 1.3)).min(20.0)
                    };
                }
                assert_matches_cold(&mut net, n, &edges, &caps, 0, n - 1);
            }
        });
    }
}
