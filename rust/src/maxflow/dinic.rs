//! Dinic's maximum-flow algorithm — the paper's solver choice (Sec. V-A).
//!
//! Level graph by BFS, blocking flow by DFS with current-arc pointers.
//! `O(V^2 E)` in general; much faster on the shallow, sparse partition DAGs
//! produced by Alg. 1/2 (the paper reports millisecond runtimes, Table I).
//!
//! The blocking-flow DFS is an explicit stack over the CSR adjacency: deep
//! chain models (a 1000-layer LLM DAG becomes a ~2000-vertex path in the
//! transformed network) would otherwise overflow the thread stack on the
//! recursion.

use super::network::{FlowNetwork, MinCut, EPS};

/// Reusable scratch buffers so repeated solves don't reallocate — the
/// coordinator re-partitions every epoch (Sec. III-A) on the hot path; the
/// planner (`partition::planner`) keeps one of these per flow network.
#[derive(Default)]
pub struct DinicScratch {
    level: Vec<i32>,
    iter: Vec<usize>,
    queue: Vec<usize>,
    /// Current DFS path as a stack of arc ids.
    path: Vec<u32>,
}

/// Run Dinic's algorithm; returns the max-flow value and the min-cut side.
pub fn dinic(net: &mut FlowNetwork, s: usize, t: usize) -> MinCut {
    let mut scratch = DinicScratch::default();
    dinic_with(net, s, t, &mut scratch)
}

/// Dinic with caller-provided scratch buffers (hot-path variant).
pub fn dinic_with(
    net: &mut FlowNetwork,
    s: usize,
    t: usize,
    scratch: &mut DinicScratch,
) -> MinCut {
    let (value, _phases) = dinic_augment(net, s, t, scratch);
    let source_side = net.residual_source_side(s);
    debug_assert!(!source_side[t], "sink on source side after max-flow");
    MinCut { value, source_side }
}

/// Augment the network's **current** residual flow to a maximum flow:
/// repeated BFS level graphs + blocking flows until the sink is
/// unreachable. Returns `(added, phases)` — the flow value pushed by this
/// call (the total max-flow value when starting from zero flow, which is
/// what [`dinic_with`] does after a capacity refresh) and the number of
/// BFS phases run. The incremental re-solver ([`super::incremental`])
/// calls this on a repaired carried flow, where few (often zero) phases
/// remain — that phase count is the `augment_rounds` it reports.
pub fn dinic_augment(
    net: &mut FlowNetwork,
    s: usize,
    t: usize,
    scratch: &mut DinicScratch,
) -> (f64, u64) {
    assert!(s != t, "source and sink must differ");
    net.freeze();
    let n = net.len();
    scratch.level.resize(n, -1);
    scratch.iter.resize(n, 0);
    let mut value = 0.0f64;
    let mut phases = 0u64;

    loop {
        // BFS: build level graph.
        let level = &mut scratch.level;
        for l in level.iter_mut() {
            *l = -1;
        }
        level[s] = 0;
        scratch.queue.clear();
        scratch.queue.push(s);
        let mut head = 0;
        while head < scratch.queue.len() {
            let v = scratch.queue[head];
            head += 1;
            for &arc in net.arcs(v) {
                let arc = arc as usize;
                let to = net.arc_to(arc);
                if level[to] < 0 && net.arc_cap(arc) > EPS {
                    level[to] = level[v] + 1;
                    scratch.queue.push(to);
                }
            }
        }
        if level[t] < 0 {
            break; // no augmenting path remains
        }
        phases += 1;

        // DFS blocking flow with current-arc optimization.
        for it in scratch.iter.iter_mut() {
            *it = 0;
        }
        loop {
            let pushed = augment(net, s, t, &mut scratch.iter, &scratch.level, &mut scratch.path);
            if pushed <= EPS {
                break;
            }
            value += pushed;
        }
    }

    (value, phases)
}

/// Find one augmenting path in the level graph and push its bottleneck
/// flow. Explicit-stack equivalent of the textbook recursion: `path` holds
/// the arcs of the partial path; advancing pushes an admissible arc,
/// retreating pops it and bumps the parent's current-arc pointer (the arc
/// is exhausted for this phase). Returns the pushed amount, 0 when no
/// admissible path remains.
fn augment(
    net: &mut FlowNetwork,
    s: usize,
    t: usize,
    iter: &mut [usize],
    level: &[i32],
    path: &mut Vec<u32>,
) -> f64 {
    path.clear();
    let mut v = s;
    loop {
        if v == t {
            let mut bottleneck = f64::INFINITY;
            for &arc in path.iter() {
                bottleneck = bottleneck.min(net.arc_cap(arc as usize));
            }
            for &arc in path.iter() {
                net.push_on(arc as usize, bottleneck);
            }
            return bottleneck;
        }
        let deg = net.arc_range(v).len();
        let mut advanced = false;
        while iter[v] < deg {
            let arc = net.arcs(v)[iter[v]] as usize;
            let to = net.arc_to(arc);
            if net.arc_cap(arc) > EPS && level[to] == level[v] + 1 {
                path.push(arc as u32);
                v = to;
                advanced = true;
                break;
            }
            iter[v] += 1;
        }
        if !advanced {
            // Dead end: no admissible arc left at `v` this phase.
            match path.pop() {
                None => return 0.0, // back at the source: blocking flow done
                Some(arc) => {
                    // Parent is the source of `arc`, i.e. the target of its
                    // residual twin.
                    v = net.arc_to(arc as usize ^ 1);
                    iter[v] += 1;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// CLRS-style 6-vertex fixture, max flow 23 (shared by the warm-refresh
    /// regression tests below).
    fn clrs_network() -> FlowNetwork {
        let mut net = FlowNetwork::new(6);
        net.add_edge(0, 1, 16.0);
        net.add_edge(0, 2, 13.0);
        net.add_edge(1, 2, 10.0);
        net.add_edge(2, 1, 4.0);
        net.add_edge(1, 3, 12.0);
        net.add_edge(3, 2, 9.0);
        net.add_edge(2, 4, 14.0);
        net.add_edge(4, 3, 7.0);
        net.add_edge(3, 5, 20.0);
        net.add_edge(4, 5, 4.0);
        net
    }

    #[test]
    fn single_edge() {
        let mut net = FlowNetwork::new(2);
        net.add_edge(0, 1, 4.5);
        let cut = dinic(&mut net, 0, 1);
        assert!((cut.value - 4.5).abs() < 1e-12);
        assert_eq!(cut.source_side, vec![true, false]);
    }

    #[test]
    fn classic_textbook_network() {
        let mut net = clrs_network();
        let cut = dinic(&mut net, 0, 5);
        assert!((cut.value - 23.0).abs() < 1e-9);
        // Min cut value recomputed from the partition must match.
        assert!((net.cut_value(&cut.source_side) - 23.0).abs() < 1e-9);
    }

    #[test]
    fn disconnected_sink_gives_zero() {
        let mut net = FlowNetwork::new(3);
        net.add_edge(0, 1, 5.0);
        let cut = dinic(&mut net, 0, 2);
        assert_eq!(cut.value, 0.0);
        assert!(!cut.source_side[2]);
    }

    #[test]
    fn infinite_edges_never_cut() {
        // s -> a (inf), a -> t (1), s -> t (2)
        let mut net = FlowNetwork::new(3);
        net.add_edge(0, 1, f64::INFINITY);
        net.add_edge(1, 2, 1.0);
        net.add_edge(0, 2, 2.0);
        let cut = dinic(&mut net, 0, 2);
        assert!((cut.value - 3.0).abs() < 1e-12);
        assert!(cut.source_side[1], "a must stay on source side");
    }

    #[test]
    fn parallel_edges_accumulate() {
        let mut net = FlowNetwork::new(2);
        net.add_edge(0, 1, 1.0);
        net.add_edge(0, 1, 2.5);
        let cut = dinic(&mut net, 0, 1);
        assert!((cut.value - 3.5).abs() < 1e-12);
    }

    #[test]
    fn reset_allows_reuse() {
        let mut net = FlowNetwork::new(2);
        net.add_edge(0, 1, 2.0);
        let a = dinic(&mut net, 0, 1).value;
        net.reset();
        let b = dinic(&mut net, 0, 1).value;
        assert_eq!(a, b);
    }

    #[test]
    fn reset_plus_csr_resolve_matches_cold_on_clrs() {
        // Regression for the CSR refactor: reset + warm re-solve through the
        // frozen adjacency must reproduce the cold cut exactly.
        let mut cold = clrs_network();
        let reference = dinic(&mut cold, 0, 5);
        let mut net = clrs_network();
        let mut scratch = DinicScratch::default();
        let first = dinic_with(&mut net, 0, 5, &mut scratch);
        net.reset();
        assert!(net.is_frozen(), "reset must not invalidate the CSR");
        let second = dinic_with(&mut net, 0, 5, &mut scratch);
        for cut in [&first, &second] {
            assert_eq!(cut.value, reference.value);
            assert_eq!(cut.source_side, reference.source_side);
        }
    }

    #[test]
    fn warm_recapacitation_matches_fresh_network() {
        // set_edge_capacity on a solved network must behave exactly like
        // building a fresh network with the new capacities.
        let mut net = clrs_network();
        let mut scratch = DinicScratch::default();
        let _ = dinic_with(&mut net, 0, 5, &mut scratch);
        // Shrink the two source edges: new max flow is 5 + 13 = 18.
        let new_caps = [5.0, 13.0, 10.0, 4.0, 12.0, 9.0, 14.0, 7.0, 20.0, 4.0];
        for (k, &c) in new_caps.iter().enumerate() {
            net.set_edge_capacity(k, c);
        }
        let warm = dinic_with(&mut net, 0, 5, &mut scratch);
        let mut fresh = FlowNetwork::new(6);
        let ends = [
            (0, 1), (0, 2), (1, 2), (2, 1), (1, 3),
            (3, 2), (2, 4), (4, 3), (3, 5), (4, 5),
        ];
        for (&(u, v), &c) in ends.iter().zip(new_caps.iter()) {
            fresh.add_edge(u, v, c);
        }
        let cold = dinic(&mut fresh, 0, 5);
        assert_eq!(warm.value, cold.value);
        assert_eq!(warm.source_side, cold.source_side);
        assert!((warm.value - 18.0).abs() < 1e-9);
    }

    #[test]
    fn deep_chain_does_not_overflow_the_stack() {
        // 60k-vertex path: the recursive DFS this replaced would blow the
        // thread stack here (~60k frames); the explicit stack must not.
        let n = 60_000;
        let mut net = FlowNetwork::new(n);
        for v in 0..n - 1 {
            net.add_edge(v, v + 1, 1.0 + (v % 7) as f64);
        }
        let cut = dinic(&mut net, 0, n - 1);
        assert!((cut.value - 1.0).abs() < 1e-12, "bottleneck is the cap-1 arc");
    }
}
