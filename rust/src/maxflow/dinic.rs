//! Dinic's maximum-flow algorithm — the paper's solver choice (Sec. V-A).
//!
//! Level graph by BFS, blocking flow by DFS with current-arc pointers.
//! `O(V^2 E)` in general; much faster on the shallow, sparse partition DAGs
//! produced by Alg. 1/2 (the paper reports millisecond runtimes, Table I).

use super::network::{FlowNetwork, MinCut, EPS};

/// Reusable scratch buffers so repeated solves don't reallocate — the
/// coordinator re-partitions every epoch (Sec. III-A) on the hot path.
#[derive(Default)]
pub struct DinicScratch {
    level: Vec<i32>,
    iter: Vec<usize>,
    queue: Vec<usize>,
}

/// Run Dinic's algorithm; returns the max-flow value and the min-cut side.
pub fn dinic(net: &mut FlowNetwork, s: usize, t: usize) -> MinCut {
    let mut scratch = DinicScratch::default();
    dinic_with(net, s, t, &mut scratch)
}

/// Dinic with caller-provided scratch buffers (hot-path variant).
pub fn dinic_with(
    net: &mut FlowNetwork,
    s: usize,
    t: usize,
    scratch: &mut DinicScratch,
) -> MinCut {
    assert!(s != t, "source and sink must differ");
    let n = net.len();
    scratch.level.resize(n, -1);
    scratch.iter.resize(n, 0);
    let mut value = 0.0f64;

    loop {
        // BFS: build level graph.
        let level = &mut scratch.level;
        for l in level.iter_mut() {
            *l = -1;
        }
        level[s] = 0;
        scratch.queue.clear();
        scratch.queue.push(s);
        let mut head = 0;
        while head < scratch.queue.len() {
            let v = scratch.queue[head];
            head += 1;
            for &arc in net.arcs(v) {
                let arc = arc as usize;
                let to = net.arc_to(arc);
                if level[to] < 0 && net.arc_cap(arc) > EPS {
                    level[to] = level[v] + 1;
                    scratch.queue.push(to);
                }
            }
        }
        if level[t] < 0 {
            break; // no augmenting path remains
        }

        // DFS blocking flow with current-arc optimization.
        for it in scratch.iter.iter_mut() {
            *it = 0;
        }
        loop {
            let pushed = dfs(net, s, t, f64::INFINITY, &mut scratch.iter, &scratch.level);
            if pushed <= EPS {
                break;
            }
            value += pushed;
        }
    }

    let source_side = net.residual_source_side(s);
    debug_assert!(!source_side[t], "sink on source side after max-flow");
    MinCut { value, source_side }
}

fn dfs(
    net: &mut FlowNetwork,
    v: usize,
    t: usize,
    limit: f64,
    iter: &mut [usize],
    level: &[i32],
) -> f64 {
    if v == t {
        return limit;
    }
    while iter[v] < net.arcs(v).len() {
        let arc = net.arcs(v)[iter[v]] as usize;
        let to = net.arc_to(arc);
        let cap = net.arc_cap(arc);
        if cap > EPS && level[to] == level[v] + 1 {
            let pushed = dfs(net, to, t, limit.min(cap), iter, level);
            if pushed > EPS {
                net.push_on(arc, pushed);
                return pushed;
            }
        }
        iter[v] += 1;
    }
    0.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_edge() {
        let mut net = FlowNetwork::new(2);
        net.add_edge(0, 1, 4.5);
        let cut = dinic(&mut net, 0, 1);
        assert!((cut.value - 4.5).abs() < 1e-12);
        assert_eq!(cut.source_side, vec![true, false]);
    }

    #[test]
    fn classic_textbook_network() {
        // CLRS-style 6-vertex network, max flow 23.
        let mut net = FlowNetwork::new(6);
        net.add_edge(0, 1, 16.0);
        net.add_edge(0, 2, 13.0);
        net.add_edge(1, 2, 10.0);
        net.add_edge(2, 1, 4.0);
        net.add_edge(1, 3, 12.0);
        net.add_edge(3, 2, 9.0);
        net.add_edge(2, 4, 14.0);
        net.add_edge(4, 3, 7.0);
        net.add_edge(3, 5, 20.0);
        net.add_edge(4, 5, 4.0);
        let cut = dinic(&mut net, 0, 5);
        assert!((cut.value - 23.0).abs() < 1e-9);
        // Min cut value recomputed from the partition must match.
        assert!((net.cut_value(&cut.source_side) - 23.0).abs() < 1e-9);
    }

    #[test]
    fn disconnected_sink_gives_zero() {
        let mut net = FlowNetwork::new(3);
        net.add_edge(0, 1, 5.0);
        let cut = dinic(&mut net, 0, 2);
        assert_eq!(cut.value, 0.0);
        assert!(!cut.source_side[2]);
    }

    #[test]
    fn infinite_edges_never_cut() {
        // s -> a (inf), a -> t (1), s -> t (2)
        let mut net = FlowNetwork::new(3);
        net.add_edge(0, 1, f64::INFINITY);
        net.add_edge(1, 2, 1.0);
        net.add_edge(0, 2, 2.0);
        let cut = dinic(&mut net, 0, 2);
        assert!((cut.value - 3.0).abs() < 1e-12);
        assert!(cut.source_side[1], "a must stay on source side");
    }

    #[test]
    fn parallel_edges_accumulate() {
        let mut net = FlowNetwork::new(2);
        net.add_edge(0, 1, 1.0);
        net.add_edge(0, 1, 2.5);
        let cut = dinic(&mut net, 0, 1);
        assert!((cut.value - 3.5).abs() < 1e-12);
    }

    #[test]
    fn reset_allows_reuse() {
        let mut net = FlowNetwork::new(2);
        net.add_edge(0, 1, 2.0);
        let a = dinic(&mut net, 0, 1).value;
        net.reset();
        let b = dinic(&mut net, 0, 1).value;
        assert_eq!(a, b);
    }
}
