//! FIFO push-relabel max-flow with the gap heuristic.
//!
//! Independent of [`super::dinic`]; used as a cross-checking oracle in
//! property tests and in the solver ablation (`experiments::ablations`,
//! DESIGN.md ablB). `O(V^3)` worst case.

use super::network::{FlowNetwork, MinCut, EPS};
use std::collections::VecDeque;

/// Run push-relabel; returns the max-flow value and min-cut side.
pub fn push_relabel(net: &mut FlowNetwork, s: usize, t: usize) -> MinCut {
    assert!(s != t);
    net.freeze();
    let n = net.len();
    let mut height = vec![0usize; n];
    let mut excess = vec![0.0f64; n];
    let mut count = vec![0usize; 2 * n + 1]; // vertices per height (gap heuristic)
    let mut active: VecDeque<usize> = VecDeque::new();
    let mut in_queue = vec![false; n];

    height[s] = n;
    count[0] = n - 1;
    count[n] = 1;

    // Saturate all source arcs (index through the CSR positions so the
    // borrow doesn't conflict with push_on).
    for i in net.arc_range(s) {
        let arc = net.arc_at(i);
        let cap = net.arc_cap(arc);
        if cap > EPS {
            let to = net.arc_to(arc);
            let amount = if cap.is_infinite() {
                // Push a finite surrogate: total finite capacity bound.
                total_finite_capacity(net)
            } else {
                cap
            };
            net.push_on(arc, amount);
            excess[to] += amount;
            excess[s] -= amount;
            if to != t && to != s && !in_queue[to] {
                active.push_back(to);
                in_queue[to] = true;
            }
        }
    }

    while let Some(v) = active.pop_front() {
        in_queue[v] = false;
        discharge(
            net,
            v,
            t,
            s,
            &mut height,
            &mut excess,
            &mut count,
            &mut active,
            &mut in_queue,
        );
    }

    let value = excess[t];
    let source_side = net.residual_source_side(s);
    MinCut { value, source_side }
}

fn total_finite_capacity(net: &FlowNetwork) -> f64 {
    let mut sum = 1.0;
    for k in 0..net.num_edges() {
        let c = net.arc_cap(2 * k) + net.arc_cap(2 * k + 1);
        if c.is_finite() {
            sum += c;
        }
    }
    sum
}

#[allow(clippy::too_many_arguments)]
fn discharge(
    net: &mut FlowNetwork,
    v: usize,
    t: usize,
    s: usize,
    height: &mut [usize],
    excess: &mut [f64],
    count: &mut [usize],
    active: &mut VecDeque<usize>,
    in_queue: &mut [bool],
) {
    let n = net.len();
    while excess[v] > EPS {
        let mut min_height = usize::MAX;
        let mut pushed_any = false;
        for i in net.arc_range(v) {
            let arc = net.arc_at(i);
            let cap = net.arc_cap(arc);
            if cap <= EPS {
                continue;
            }
            let to = net.arc_to(arc);
            if height[v] == height[to] + 1 {
                // Push.
                let amount = excess[v].min(cap);
                net.push_on(arc, amount);
                excess[v] -= amount;
                excess[to] += amount;
                if to != s && to != t && !in_queue[to] {
                    active.push_back(to);
                    in_queue[to] = true;
                }
                pushed_any = true;
                if excess[v] <= EPS {
                    break;
                }
            } else {
                min_height = min_height.min(height[to]);
            }
        }
        if excess[v] > EPS && !pushed_any {
            // Relabel (with gap heuristic).
            if min_height == usize::MAX {
                break; // no residual arcs at all
            }
            let old = height[v];
            count[old] -= 1;
            if count[old] == 0 && old < n {
                // Gap: lift all vertices above the gap beyond n.
                for h in height.iter_mut() {
                    if *h > old && *h < n {
                        count[*h] -= 1;
                        *h = n + 1;
                        count[n + 1] += 1;
                    }
                }
            }
            height[v] = (min_height + 1).min(2 * n);
            count[height[v]] += 1;
            if height[v] >= 2 * n {
                break; // unreachable from sink; excess flows back eventually
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::maxflow::dinic;
    use crate::util::prop::for_all;

    #[test]
    fn matches_dinic_on_textbook_network() {
        let build = || {
            let mut net = FlowNetwork::new(6);
            net.add_edge(0, 1, 16.0);
            net.add_edge(0, 2, 13.0);
            net.add_edge(1, 2, 10.0);
            net.add_edge(2, 1, 4.0);
            net.add_edge(1, 3, 12.0);
            net.add_edge(3, 2, 9.0);
            net.add_edge(2, 4, 14.0);
            net.add_edge(4, 3, 7.0);
            net.add_edge(3, 5, 20.0);
            net.add_edge(4, 5, 4.0);
            net
        };
        let d = dinic(&mut build(), 0, 5).value;
        let p = push_relabel(&mut build(), 0, 5).value;
        assert!((d - p).abs() < 1e-9, "dinic={d} pr={p}");
        assert!((p - 23.0).abs() < 1e-9);
    }

    #[test]
    fn agrees_with_dinic_on_random_networks() {
        for_all("pr-vs-dinic", 60, |rng| {
            let n = 2 + rng.index(14);
            let mut edges = Vec::new();
            for u in 0..n {
                for v in 0..n {
                    if u != v && rng.chance(0.3) {
                        edges.push((u, v, rng.range(0.0, 10.0)));
                    }
                }
            }
            let build = |edges: &[(usize, usize, f64)]| {
                let mut net = FlowNetwork::new(n);
                for &(u, v, c) in edges {
                    net.add_edge(u, v, c);
                }
                net
            };
            let s = 0;
            let t = n - 1;
            let mut net_d = build(&edges);
            let mut net_p = build(&edges);
            let d = dinic(&mut net_d, s, t);
            let p = push_relabel(&mut net_p, s, t);
            assert!(
                (d.value - p.value).abs() < 1e-6 * (1.0 + d.value.abs()),
                "dinic={} push_relabel={}",
                d.value,
                p.value
            );
            // Both extracted cuts must be valid cuts of value == flow.
            assert!((net_d.cut_value(&d.source_side) - d.value).abs() < 1e-6 * (1.0 + d.value));
            assert!((net_p.cut_value(&p.source_side) - p.value).abs() < 1e-6 * (1.0 + p.value));
        });
    }

    #[test]
    fn handles_infinite_source_arc() {
        let mut net = FlowNetwork::new(3);
        net.add_edge(0, 1, f64::INFINITY);
        net.add_edge(1, 2, 2.0);
        let cut = push_relabel(&mut net, 0, 2);
        assert!((cut.value - 2.0).abs() < 1e-9);
        assert!(cut.source_side[1]);
    }
}
