//! Residual flow network representation shared by both solvers.

/// Tolerance for treating residual capacity as zero (capacities are delays
/// in seconds; 1e-15 s is far below any meaningful delay).
pub const EPS: f64 = 1e-15;

/// A directed flow network stored as paired residual arcs.
///
/// Arc `2k` is the forward arc of edge `k`, arc `2k+1` its residual twin.
#[derive(Clone, Debug)]
pub struct FlowNetwork {
    /// arc target vertex
    to: Vec<usize>,
    /// residual capacity per arc
    cap: Vec<f64>,
    /// adjacency: arc ids per vertex
    adj: Vec<Vec<u32>>,
    /// original capacity of each forward arc (for flow reporting)
    orig_cap: Vec<f64>,
    n: usize,
}

/// Result of a min-cut computation.
#[derive(Clone, Debug)]
pub struct MinCut {
    /// Max-flow value == min-cut value.
    pub value: f64,
    /// `true` for vertices on the source side of the cut.
    pub source_side: Vec<bool>,
}

impl FlowNetwork {
    pub fn new(n: usize) -> FlowNetwork {
        FlowNetwork {
            to: Vec::new(),
            cap: Vec::new(),
            adj: vec![Vec::new(); n],
            orig_cap: Vec::new(),
            n,
        }
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    pub fn num_edges(&self) -> usize {
        self.to.len() / 2
    }

    /// Add a directed edge with the given capacity (may be `INFINITY`).
    pub fn add_edge(&mut self, from: usize, to: usize, capacity: f64) -> usize {
        assert!(from < self.n && to < self.n);
        assert!(capacity >= 0.0, "negative capacity");
        let id = self.to.len();
        self.to.push(to);
        self.cap.push(capacity);
        self.adj[from].push(id as u32);
        self.to.push(from);
        self.cap.push(0.0);
        self.adj[to].push(id as u32 + 1);
        self.orig_cap.push(capacity);
        id / 2
    }

    #[inline]
    pub(crate) fn arc_to(&self, arc: usize) -> usize {
        self.to[arc]
    }

    #[inline]
    pub(crate) fn arc_cap(&self, arc: usize) -> f64 {
        self.cap[arc]
    }

    #[inline]
    pub(crate) fn push_on(&mut self, arc: usize, amount: f64) {
        self.cap[arc] -= amount;
        self.cap[arc ^ 1] += amount;
    }

    #[inline]
    pub(crate) fn arcs(&self, v: usize) -> &[u32] {
        &self.adj[v]
    }

    /// Flow currently routed through forward edge `k`.
    pub fn flow_on(&self, edge: usize) -> f64 {
        let forward = 2 * edge;
        if self.orig_cap[edge].is_infinite() {
            // flow = residual of the twin arc
            self.cap[forward ^ 1]
        } else {
            self.orig_cap[edge] - self.cap[forward]
        }
    }

    /// After a max-flow run, extract the source side of the min cut: the set
    /// of vertices reachable from `s` in the residual graph.
    pub fn residual_source_side(&self, s: usize) -> Vec<bool> {
        let mut seen = vec![false; self.n];
        let mut stack = vec![s];
        seen[s] = true;
        while let Some(v) = stack.pop() {
            for &arc in &self.adj[v] {
                let arc = arc as usize;
                if self.cap[arc] > EPS {
                    let to = self.to[arc];
                    if !seen[to] {
                        seen[to] = true;
                        stack.push(to);
                    }
                }
            }
        }
        seen
    }

    /// Reset all arcs to their original capacities (reuse between solves).
    pub fn reset(&mut self) {
        for k in 0..self.orig_cap.len() {
            self.cap[2 * k] = self.orig_cap[k];
            self.cap[2 * k + 1] = 0.0;
        }
    }

    /// Sum of capacities crossing a given vertex bipartition (cut value
    /// computed directly — used by tests to validate solver results).
    pub fn cut_value(&self, source_side: &[bool]) -> f64 {
        let mut total = 0.0;
        for k in 0..self.orig_cap.len() {
            let from = self.to[2 * k + 1];
            let to = self.to[2 * k];
            if source_side[from] && !source_side[to] {
                total += self.orig_cap[k];
            }
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_edge_and_flow_bookkeeping() {
        let mut net = FlowNetwork::new(2);
        let e = net.add_edge(0, 1, 5.0);
        assert_eq!(net.flow_on(e), 0.0);
        net.push_on(2 * e, 3.0);
        assert_eq!(net.flow_on(e), 3.0);
        assert_eq!(net.arc_cap(2 * e), 2.0);
        assert_eq!(net.arc_cap(2 * e + 1), 3.0);
        net.reset();
        assert_eq!(net.flow_on(e), 0.0);
    }

    #[test]
    fn cut_value_counts_forward_edges_only() {
        let mut net = FlowNetwork::new(3);
        net.add_edge(0, 1, 2.0);
        net.add_edge(1, 2, 3.0);
        net.add_edge(2, 0, 7.0); // backward across the cut below
        let cut = net.cut_value(&[true, false, false]);
        assert_eq!(cut, 2.0);
    }
}
