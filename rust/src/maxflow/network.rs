//! Residual flow network representation shared by both solvers.
//!
//! Storage is a frozen CSR adjacency: `offsets[v]..offsets[v+1]` indexes a
//! flat `arcs` array of arc ids, built once by [`FlowNetwork::freeze`] (or
//! lazily by the first solve) with a stable counting sort. Compared to the
//! previous `Vec<Vec<u32>>` adjacency this removes one heap allocation per
//! vertex and makes the solvers' BFS/DFS scans cache-friendly — the
//! coordinator re-solves the same network every epoch (Sec. III-A), so the
//! build cost is paid once and the scan cost every epoch.
//!
//! Capacity mutation never invalidates the CSR: [`FlowNetwork::reset`] and
//! [`FlowNetwork::set_edge_capacity`] touch only the capacity arrays, which
//! is what enables the planner's O(E) warm refresh (see
//! `partition::planner`). Only [`FlowNetwork::add_edge`] invalidates it.

/// Tolerance for treating residual capacity as zero (capacities are delays
/// in seconds; 1e-15 s is far below any meaningful delay).
pub const EPS: f64 = 1e-15;

/// A directed flow network stored as paired residual arcs.
///
/// Arc `2k` is the forward arc of edge `k`, arc `2k+1` its residual twin.
#[derive(Clone, Debug)]
pub struct FlowNetwork {
    /// arc target vertex
    to: Vec<u32>,
    /// residual capacity per arc
    cap: Vec<f64>,
    /// original capacity of each forward arc (for flow reporting / reset)
    orig_cap: Vec<f64>,
    /// CSR adjacency: arc ids of vertex `v` are
    /// `arcs[offsets[v] as usize .. offsets[v+1] as usize]`.
    offsets: Vec<u32>,
    arcs: Vec<u32>,
    /// True while `offsets`/`arcs` reflect the current arc set.
    frozen: bool,
    n: usize,
}

/// Result of a min-cut computation.
#[derive(Clone, Debug)]
pub struct MinCut {
    /// Max-flow value == min-cut value.
    pub value: f64,
    /// `true` for vertices on the source side of the cut.
    pub source_side: Vec<bool>,
}

impl FlowNetwork {
    pub fn new(n: usize) -> FlowNetwork {
        FlowNetwork::with_capacity(n, 0)
    }

    /// Preallocate for `edges` forward edges (the planner knows the exact
    /// count of the transformed DAG up front).
    pub fn with_capacity(n: usize, edges: usize) -> FlowNetwork {
        FlowNetwork {
            to: Vec::with_capacity(2 * edges),
            cap: Vec::with_capacity(2 * edges),
            orig_cap: Vec::with_capacity(edges),
            offsets: Vec::new(),
            arcs: Vec::new(),
            frozen: false,
            n,
        }
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    pub fn num_edges(&self) -> usize {
        self.orig_cap.len()
    }

    /// Add a directed edge with the given capacity (may be `INFINITY`).
    /// Invalidates the frozen adjacency (rebuilt on the next solve).
    pub fn add_edge(&mut self, from: usize, to: usize, capacity: f64) -> usize {
        assert!(from < self.n && to < self.n);
        assert!(capacity >= 0.0, "negative capacity");
        let id = self.orig_cap.len();
        debug_assert!(self.to.len() == 2 * id);
        self.to.push(to as u32);
        self.cap.push(capacity);
        self.to.push(from as u32);
        self.cap.push(0.0);
        self.orig_cap.push(capacity);
        self.frozen = false;
        id
    }

    /// Source vertex of an arc (the target of its residual twin).
    #[inline]
    fn arc_src(&self, arc: usize) -> usize {
        self.to[arc ^ 1] as usize
    }

    /// Build the CSR adjacency with a stable counting sort over arc
    /// sources. Arc order within a vertex is insertion order, matching the
    /// old per-vertex `Vec` layout (solver traversal order is unchanged).
    pub fn freeze(&mut self) {
        if self.frozen {
            return;
        }
        let m = self.to.len();
        self.offsets.clear();
        self.offsets.resize(self.n + 1, 0);
        for arc in 0..m {
            let s = self.arc_src(arc);
            self.offsets[s + 1] += 1;
        }
        for v in 0..self.n {
            self.offsets[v + 1] += self.offsets[v];
        }
        // Fill through a separate cursor copy so `offsets` itself stays
        // untouched (cursor[v] ends exactly at offsets[v+1]).
        let mut cursor: Vec<u32> = self.offsets[..self.n].to_vec();
        self.arcs.clear();
        self.arcs.resize(m, 0);
        for arc in 0..m {
            let s = self.arc_src(arc);
            self.arcs[cursor[s] as usize] = arc as u32;
            cursor[s] += 1;
        }
        self.frozen = true;
    }

    /// Whether the CSR adjacency is current.
    pub fn is_frozen(&self) -> bool {
        self.frozen
    }

    #[inline]
    pub(crate) fn arc_to(&self, arc: usize) -> usize {
        self.to[arc] as usize
    }

    #[inline]
    pub(crate) fn arc_cap(&self, arc: usize) -> f64 {
        self.cap[arc]
    }

    #[inline]
    pub(crate) fn push_on(&mut self, arc: usize, amount: f64) {
        self.cap[arc] -= amount;
        self.cap[arc ^ 1] += amount;
    }

    /// Arc ids leaving vertex `v`. Requires a frozen network.
    #[inline]
    pub(crate) fn arcs(&self, v: usize) -> &[u32] {
        debug_assert!(self.frozen, "call freeze() before traversing");
        &self.arcs[self.offsets[v] as usize..self.offsets[v + 1] as usize]
    }

    /// Index range of `v`'s arcs in the flat CSR array (for solvers that
    /// need to interleave traversal with capacity mutation).
    #[inline]
    pub(crate) fn arc_range(&self, v: usize) -> std::ops::Range<usize> {
        debug_assert!(self.frozen, "call freeze() before traversing");
        self.offsets[v] as usize..self.offsets[v + 1] as usize
    }

    /// Arc id stored at CSR position `i` (see [`FlowNetwork::arc_range`]).
    #[inline]
    pub(crate) fn arc_at(&self, i: usize) -> usize {
        self.arcs[i] as usize
    }

    /// Flow currently routed through forward edge `k`.
    pub fn flow_on(&self, edge: usize) -> f64 {
        let forward = 2 * edge;
        if self.orig_cap[edge].is_infinite() {
            // flow = residual of the twin arc
            self.cap[forward ^ 1]
        } else {
            self.orig_cap[edge] - self.cap[forward]
        }
    }

    /// After a max-flow run, extract the source side of the min cut: the set
    /// of vertices reachable from `s` in the residual graph.
    pub fn residual_source_side(&self, s: usize) -> Vec<bool> {
        let mut seen = vec![false; self.n];
        let mut stack = vec![s];
        seen[s] = true;
        while let Some(v) = stack.pop() {
            for &arc in self.arcs(v) {
                let arc = arc as usize;
                if self.cap[arc] > EPS {
                    let to = self.to[arc] as usize;
                    if !seen[to] {
                        seen[to] = true;
                        stack.push(to);
                    }
                }
            }
        }
        seen
    }

    /// Reset all arcs to their original capacities (reuse between solves).
    /// Touches only capacities; the frozen adjacency stays valid.
    pub fn reset(&mut self) {
        for k in 0..self.orig_cap.len() {
            self.cap[2 * k] = self.orig_cap[k];
            self.cap[2 * k + 1] = 0.0;
        }
    }

    /// Re-capacitate forward edge `k` and clear any routed flow on it: the
    /// planner's warm-refresh primitive. Writing every edge between solves
    /// is equivalent to rebuilding the network from scratch with the new
    /// capacities (and is what `partition::planner` does each epoch); the
    /// frozen adjacency stays valid because topology is untouched.
    #[inline]
    pub fn set_edge_capacity(&mut self, edge: usize, capacity: f64) {
        debug_assert!(capacity >= 0.0, "negative capacity");
        self.orig_cap[edge] = capacity;
        self.cap[2 * edge] = capacity;
        self.cap[2 * edge + 1] = 0.0;
    }

    /// Re-capacitate forward edge `k` **preserving** its routed flow: the
    /// flow-reusing refresh primitive of [`super::incremental`]. The edge
    /// keeps `min(flow, capacity)` units routed; the returned value is the
    /// amount by which the carried flow exceeded the new capacity (0 when
    /// none). A positive return leaves the flow *unbalanced* at the edge's
    /// endpoints — the caller must repair conservation (see
    /// [`super::incremental::IncrementalScratch::resolve`]) before treating
    /// the network state as a feasible flow again.
    ///
    /// Relies on the arc-pair invariant that the residual twin `2k+1`
    /// always holds exactly the routed flow (true for both finite and
    /// infinite forward capacities under `add_edge`/`set_edge_capacity`/
    /// `push_on`/`reset`, and preserved here).
    #[inline]
    pub fn update_edge_capacity(&mut self, edge: usize, capacity: f64) -> f64 {
        debug_assert!(capacity >= 0.0, "negative capacity");
        let flow = self.cap[2 * edge + 1];
        let kept = flow.min(capacity);
        self.orig_cap[edge] = capacity;
        self.cap[2 * edge] = capacity - kept; // INF - finite = INF
        self.cap[2 * edge + 1] = kept;
        flow - kept
    }

    /// Tail and head vertex of forward edge `k`.
    #[inline]
    pub fn edge_endpoints(&self, edge: usize) -> (usize, usize) {
        (self.to[2 * edge + 1] as usize, self.to[2 * edge] as usize)
    }

    /// Net flow currently leaving vertex `v` (outgoing minus incoming
    /// routed flow). At the source this is the flow *value*; the
    /// incremental re-solver reads it instead of carrying value
    /// bookkeeping through the repair passes. Requires a frozen network.
    pub fn outflow(&self, v: usize) -> f64 {
        let mut sum = 0.0;
        for &arc in self.arcs(v) {
            let arc = arc as usize;
            // The odd twin of each pair holds the pair's routed flow.
            let flow = self.cap[arc | 1];
            if arc & 1 == 0 {
                sum += flow;
            } else {
                sum -= flow;
            }
        }
        sum
    }

    /// Sum of capacities crossing a given vertex bipartition (cut value
    /// computed directly — used by tests to validate solver results).
    pub fn cut_value(&self, source_side: &[bool]) -> f64 {
        let mut total = 0.0;
        for k in 0..self.orig_cap.len() {
            let from = self.to[2 * k + 1] as usize;
            let to = self.to[2 * k] as usize;
            if source_side[from] && !source_side[to] {
                total += self.orig_cap[k];
            }
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_edge_and_flow_bookkeeping() {
        let mut net = FlowNetwork::new(2);
        let e = net.add_edge(0, 1, 5.0);
        assert_eq!(net.flow_on(e), 0.0);
        net.push_on(2 * e, 3.0);
        assert_eq!(net.flow_on(e), 3.0);
        assert_eq!(net.arc_cap(2 * e), 2.0);
        assert_eq!(net.arc_cap(2 * e + 1), 3.0);
        net.reset();
        assert_eq!(net.flow_on(e), 0.0);
    }

    #[test]
    fn cut_value_counts_forward_edges_only() {
        let mut net = FlowNetwork::new(3);
        net.add_edge(0, 1, 2.0);
        net.add_edge(1, 2, 3.0);
        net.add_edge(2, 0, 7.0); // backward across the cut below
        let cut = net.cut_value(&[true, false, false]);
        assert_eq!(cut, 2.0);
    }

    #[test]
    fn csr_preserves_insertion_order_per_vertex() {
        let mut net = FlowNetwork::new(3);
        let a = net.add_edge(0, 1, 1.0); // arc 2a
        let b = net.add_edge(0, 2, 1.0); // arc 2b
        let c = net.add_edge(1, 2, 1.0); // arc 2c, twin 2c+1 at vertex 2
        net.freeze();
        assert_eq!(net.arcs(0), &[2 * a as u32, 2 * b as u32][..]);
        assert_eq!(net.arcs(1), &[(2 * a + 1) as u32, 2 * c as u32][..]);
        assert_eq!(net.arcs(2), &[(2 * b + 1) as u32, (2 * c + 1) as u32][..]);
    }

    #[test]
    fn add_edge_invalidates_and_refreeze_extends() {
        let mut net = FlowNetwork::new(3);
        net.add_edge(0, 1, 1.0);
        net.freeze();
        assert!(net.is_frozen());
        let e = net.add_edge(1, 2, 4.0);
        assert!(!net.is_frozen());
        net.freeze();
        assert_eq!(net.arcs(1).len(), 2); // twin of edge 0 + forward of e
        assert_eq!(net.flow_on(e), 0.0);
    }

    #[test]
    fn update_edge_capacity_preserves_flow_and_reports_violation() {
        let mut net = FlowNetwork::new(2);
        let e = net.add_edge(0, 1, 5.0);
        net.push_on(2 * e, 3.0);
        // Raising the capacity keeps the flow and reports no violation.
        assert_eq!(net.update_edge_capacity(e, 7.0), 0.0);
        assert_eq!(net.flow_on(e), 3.0);
        assert_eq!(net.arc_cap(2 * e), 4.0);
        // Cutting below the carried flow clamps it and reports the excess.
        assert_eq!(net.update_edge_capacity(e, 1.0), 2.0);
        assert_eq!(net.flow_on(e), 1.0);
        assert_eq!(net.arc_cap(2 * e), 0.0);
        assert_eq!(net.arc_cap(2 * e + 1), 1.0);
        // Infinite capacity keeps the residual infinite.
        assert_eq!(net.update_edge_capacity(e, f64::INFINITY), 0.0);
        assert_eq!(net.flow_on(e), 1.0);
        assert!(net.arc_cap(2 * e).is_infinite());
    }

    #[test]
    fn edge_endpoints_and_outflow() {
        let mut net = FlowNetwork::new(3);
        let a = net.add_edge(0, 1, 4.0);
        let b = net.add_edge(1, 2, 4.0);
        assert_eq!(net.edge_endpoints(a), (0, 1));
        assert_eq!(net.edge_endpoints(b), (1, 2));
        net.freeze();
        net.push_on(2 * a, 2.5);
        net.push_on(2 * b, 2.5);
        assert_eq!(net.outflow(0), 2.5);
        assert_eq!(net.outflow(1), 0.0);
        assert_eq!(net.outflow(2), -2.5);
    }

    #[test]
    fn set_edge_capacity_recapacitates_and_clears_flow() {
        let mut net = FlowNetwork::new(2);
        let e = net.add_edge(0, 1, 5.0);
        net.push_on(2 * e, 3.0);
        net.set_edge_capacity(e, 7.5);
        assert_eq!(net.flow_on(e), 0.0);
        assert_eq!(net.arc_cap(2 * e), 7.5);
        assert_eq!(net.arc_cap(2 * e + 1), 0.0);
    }
}
