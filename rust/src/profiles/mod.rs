//! Device/server compute-cost profiles.
//!
//! The paper profiles per-layer forward/backward times with PyTorch hooks
//! on a Jetson testbed (Sec. VII-B.1: 5x TX1, 5x TX2, 5x Orin Nano,
//! 5x AGX Orin, server with RTX A6000). Offline we substitute an analytic
//! cost model: `delay = flops * batch * (1 + bwd_ratio) / throughput +
//! overhead`, with effective throughputs calibrated to the hardware tiers
//! (DESIGN.md §Substitutions). What the partition algorithms consume is
//! only the per-layer ξ_D / ξ_S vectors, so any profile satisfying
//! Assumption 1 exercises the identical code paths.

pub mod devices;
pub mod cost;

pub use cost::{CostGraph, TrainCfg};
pub use devices::DeviceProfile;
