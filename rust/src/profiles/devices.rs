//! Hardware tiers of the paper's prototype (Fig. 10) as analytic profiles.

/// Compute profile of one device (or the server).
#[derive(Clone, Debug, PartialEq)]
pub struct DeviceProfile {
    pub name: &'static str,
    /// Effective sustained training throughput in FLOP/s (fp32, achievable
    /// fraction of peak — not spec-sheet peak).
    pub flops_per_sec: f64,
    /// Fixed per-layer launch/dispatch overhead in seconds.
    pub layer_overhead: f64,
}

impl DeviceProfile {
    /// NVIDIA Jetson TX1 (256-core Maxwell): ~1 TFLOPS fp16 peak,
    /// ~0.25 effective fp32 training.
    pub fn jetson_tx1() -> DeviceProfile {
        DeviceProfile {
            name: "jetson-tx1",
            flops_per_sec: 0.25e12,
            layer_overhead: 250e-6,
        }
    }

    /// NVIDIA Jetson TX2 (256-core Pascal): ~1.33 TFLOPS fp16 peak.
    pub fn jetson_tx2() -> DeviceProfile {
        DeviceProfile {
            name: "jetson-tx2",
            flops_per_sec: 0.35e12,
            layer_overhead: 220e-6,
        }
    }

    /// NVIDIA Jetson Orin Nano (1024-core Ampere).
    pub fn jetson_orin_nano() -> DeviceProfile {
        DeviceProfile {
            name: "jetson-orin-nano",
            flops_per_sec: 1.3e12,
            layer_overhead: 150e-6,
        }
    }

    /// NVIDIA Jetson AGX Orin (2048-core Ampere).
    pub fn jetson_agx_orin() -> DeviceProfile {
        DeviceProfile {
            name: "jetson-agx-orin",
            flops_per_sec: 4.5e12,
            layer_overhead: 120e-6,
        }
    }

    /// Server PC with one RTX A6000 (38.7 TFLOPS fp32 peak; ~50% achievable
    /// on training workloads).
    pub fn rtx_a6000() -> DeviceProfile {
        DeviceProfile {
            name: "rtx-a6000",
            flops_per_sec: 19.0e12,
            layer_overhead: 40e-6,
        }
    }

    /// The paper's 20-device fleet: 5 of each Jetson tier (Sec. VII-B.1).
    pub fn paper_fleet() -> Vec<DeviceProfile> {
        let mut fleet = Vec::new();
        for _ in 0..5 {
            fleet.push(DeviceProfile::jetson_tx1());
        }
        for _ in 0..5 {
            fleet.push(DeviceProfile::jetson_tx2());
        }
        for _ in 0..5 {
            fleet.push(DeviceProfile::jetson_orin_nano());
        }
        for _ in 0..5 {
            fleet.push(DeviceProfile::jetson_agx_orin());
        }
        fleet
    }

    /// A fleet of `n` devices cycling through the four Jetson tiers.
    pub fn fleet_of(n: usize) -> Vec<DeviceProfile> {
        let tiers = [
            DeviceProfile::jetson_tx1(),
            DeviceProfile::jetson_tx2(),
            DeviceProfile::jetson_orin_nano(),
            DeviceProfile::jetson_agx_orin(),
        ];
        (0..n).map(|i| tiers[i % 4].clone()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn server_dominates_every_device() {
        // Assumption 1 (Eq. 16) requires the server at least as fast.
        let server = DeviceProfile::rtx_a6000();
        for d in DeviceProfile::paper_fleet() {
            assert!(server.flops_per_sec > d.flops_per_sec, "{}", d.name);
            assert!(server.layer_overhead <= d.layer_overhead, "{}", d.name);
        }
    }

    #[test]
    fn fleet_sizes() {
        assert_eq!(DeviceProfile::paper_fleet().len(), 20);
        assert_eq!(DeviceProfile::fleet_of(10).len(), 10);
        assert_eq!(DeviceProfile::fleet_of(40).len(), 40);
    }

    #[test]
    fn tiers_are_ordered() {
        let f = [
            DeviceProfile::jetson_tx1(),
            DeviceProfile::jetson_tx2(),
            DeviceProfile::jetson_orin_nano(),
            DeviceProfile::jetson_agx_orin(),
        ];
        for w in f.windows(2) {
            assert!(w[0].flops_per_sec < w[1].flops_per_sec);
        }
    }
}
