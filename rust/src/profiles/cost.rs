//! Cost graph: the layer DAG annotated with everything the partitioning
//! problem needs — per-layer device/server compute delays (ξ_D, ξ_S),
//! smashed-data bytes (a) and parameter bytes (k).
//!
//! This is the interface between the model zoo / profiler and the
//! partition algorithms: Alg. 1-4 and all baselines consume a [`CostGraph`]
//! only, so they work identically for measured or analytic profiles and
//! for block-reduced graphs.

use super::devices::DeviceProfile;
use crate::graph::Dag;
use crate::models::ModelGraph;

/// Training configuration entering the delay model (Sec. III-B).
#[derive(Clone, Copy, Debug)]
pub struct TrainCfg {
    /// Mini-batch size (activations scale with it; Sec. VII-B.6 uses 32).
    pub batch: usize,
    /// Local iterations per epoch, `N_loc` in Eq. (7).
    pub n_loc: u32,
    /// Backward/forward FLOPs ratio (standard 2:1 for training).
    pub bwd_ratio: f64,
}

impl Default for TrainCfg {
    fn default() -> Self {
        TrainCfg {
            batch: 32,
            n_loc: 10,
            bwd_ratio: 2.0,
        }
    }
}

/// The partitioning problem's view of a model: DAG + per-layer costs.
#[derive(Clone, Debug)]
pub struct CostGraph {
    /// Layer dependency DAG (vertex ids match the cost vectors).
    pub dag: Dag,
    /// ξ_D: fwd+bwd compute delay of each layer on the device (seconds).
    pub xi_d: Vec<f64>,
    /// ξ_S: fwd+bwd compute delay of each layer on the server (seconds).
    pub xi_s: Vec<f64>,
    /// a_v: smashed-data bytes for a full mini-batch per layer output.
    pub act_bytes: Vec<f64>,
    /// k_v: parameter bytes per layer.
    pub param_bytes: Vec<f64>,
    /// N_loc.
    pub n_loc: f64,
}

impl CostGraph {
    /// Build from a zoo model + device/server profiles + training config.
    pub fn build(
        model: &ModelGraph,
        device: &DeviceProfile,
        server: &DeviceProfile,
        cfg: &TrainCfg,
    ) -> CostGraph {
        let n = model.len();
        let mut xi_d = Vec::with_capacity(n);
        let mut xi_s = Vec::with_capacity(n);
        let mut act_bytes = Vec::with_capacity(n);
        let mut param_bytes = Vec::with_capacity(n);
        for l in model.layers() {
            let train_flops = l.flops as f64 * cfg.batch as f64 * (1.0 + cfg.bwd_ratio);
            // The input layer is free: it is the data source.
            let (d, s) = if train_flops == 0.0 && l.params == 0 {
                (0.0, 0.0)
            } else {
                (
                    train_flops / device.flops_per_sec + device.layer_overhead,
                    train_flops / server.flops_per_sec + server.layer_overhead,
                )
            };
            xi_d.push(d);
            xi_s.push(s);
            act_bytes.push(l.act_bytes() as f64 * cfg.batch as f64);
            param_bytes.push(l.param_bytes() as f64);
        }
        CostGraph {
            dag: model.dag().clone(),
            xi_d,
            xi_s,
            act_bytes,
            param_bytes,
            n_loc: cfg.n_loc as f64,
        }
    }

    pub fn len(&self) -> usize {
        self.xi_d.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xi_d.is_empty()
    }

    /// Assumption 1 (Eq. 16): ξ_D >= ξ_S for every layer.
    pub fn satisfies_assumption1(&self) -> bool {
        self.xi_d
            .iter()
            .zip(&self.xi_s)
            .all(|(&d, &s)| d >= s - 1e-15)
    }

    /// Total device-side compute delay if everything ran on the device.
    pub fn total_device_compute(&self) -> f64 {
        self.xi_d.iter().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;

    #[test]
    fn cost_graph_dimensions_match_model() {
        let m = models::by_name("resnet18").unwrap();
        let cg = CostGraph::build(
            &m,
            &DeviceProfile::jetson_tx2(),
            &DeviceProfile::rtx_a6000(),
            &TrainCfg::default(),
        );
        assert_eq!(cg.len(), m.len());
        assert_eq!(cg.dag.num_edges(), m.dag().num_edges());
        assert!(cg.satisfies_assumption1());
    }

    #[test]
    fn input_layer_is_free() {
        let m = models::by_name("lenet5").unwrap();
        let cg = CostGraph::build(
            &m,
            &DeviceProfile::jetson_tx1(),
            &DeviceProfile::rtx_a6000(),
            &TrainCfg::default(),
        );
        assert_eq!(cg.xi_d[0], 0.0);
        assert_eq!(cg.xi_s[0], 0.0);
        assert_eq!(cg.param_bytes[0], 0.0);
        assert!(cg.act_bytes[0] > 0.0, "input activation is the raw batch");
    }

    #[test]
    fn batch_scales_activations_linearly() {
        let m = models::by_name("lenet5").unwrap();
        let mk = |batch| {
            CostGraph::build(
                &m,
                &DeviceProfile::jetson_tx1(),
                &DeviceProfile::rtx_a6000(),
                &TrainCfg {
                    batch,
                    ..TrainCfg::default()
                },
            )
        };
        let a = mk(8);
        let b = mk(16);
        for v in 0..a.len() {
            assert!((b.act_bytes[v] - 2.0 * a.act_bytes[v]).abs() < 1e-9);
            // Parameters do not scale with batch.
            assert_eq!(a.param_bytes[v], b.param_bytes[v]);
        }
    }

    #[test]
    fn faster_device_lowers_xi_d() {
        let m = models::by_name("googlenet").unwrap();
        let cfg = TrainCfg::default();
        let slow = CostGraph::build(
            &m,
            &DeviceProfile::jetson_tx1(),
            &DeviceProfile::rtx_a6000(),
            &cfg,
        );
        let fast = CostGraph::build(
            &m,
            &DeviceProfile::jetson_agx_orin(),
            &DeviceProfile::rtx_a6000(),
            &cfg,
        );
        assert!(fast.total_device_compute() < slow.total_device_compute());
    }
}
