//! Fig. 12: per-epoch training-delay traces in the mmWave network under a
//! Rayleigh fading channel — the proposed solution stays stable while the
//! static OSS cut swings with the channel.

use crate::net::{Band, ChannelCondition, NetConfig};
use crate::sim::{SimConfig, Trainer};
use crate::util::stats::Summary;
use crate::util::table::Table;

pub fn run(epochs: usize) -> String {
    let mut t = Table::new(&["method", "mean (s)", "std (s)", "p95 (s)", "max (s)", "cv"]);
    let mut trace = String::new();
    for method in ["proposed", "oss", "device-only", "regression"] {
        let cfg = SimConfig {
            model: "googlenet".into(),
            net: NetConfig {
                band: Band::n257(),
                condition: ChannelCondition::Normal,
                rayleigh: true,
                ..NetConfig::default()
            },
            method: method.to_string(),
            seed: 23,
            ..SimConfig::default()
        };
        let mut trainer = Trainer::new(cfg);
        let res = trainer.run_epochs(epochs);
        let delays: Vec<f64> = res.records.iter().map(|r| r.delay).collect();
        let s = Summary::of(&delays);
        t.row(&[
            method.to_string(),
            format!("{:.1}", s.mean),
            format!("{:.1}", s.std_dev),
            format!("{:.1}", s.p95),
            format!("{:.1}", s.max),
            format!("{:.2}", s.std_dev / s.mean),
        ]);
        if method == "proposed" || method == "oss" {
            let head: Vec<String> = delays.iter().take(12).map(|d| format!("{d:.0}")).collect();
            trace.push_str(&format!("  {method:<10} first epochs: {}\n", head.join(" ")));
        }
    }
    format!(
        "Fig 12: per-epoch delay under Rayleigh fading, mmWave, {epochs} epochs\n{}\ntraces:\n{trace}",
        t.render()
    )
}

#[cfg(test)]
mod tests {
    #[test]
    fn proposed_has_lower_variability_than_oss() {
        let out = super::run(40);
        // Extract cv column for proposed and oss.
        let cv = |method: &str| -> f64 {
            out.lines()
                .find(|l| l.starts_with(method))
                .and_then(|l| l.split_whitespace().last())
                .and_then(|v| v.parse().ok())
                .unwrap()
        };
        // Coefficient of variation: proposed adapts, oss doesn't. Allow
        // some slack for the stochastic channel.
        assert!(cv("proposed") <= cv("oss") * 1.5, "{out}");
    }
}
