//! Fig. 8: computational complexity on the four full AI models.

use super::common::cost_graph;
use crate::models::FULL_MODELS;
use crate::partition::baselines::brute_force_complexity;
use crate::partition::blockwise::blockwise_partition_instrumented;
use crate::partition::general::general_partition_instrumented;
use crate::partition::{Link, Problem};
use crate::util::table::Table;

pub fn run() -> String {
    let mut t = Table::new(&[
        "model",
        "layers",
        "brute-force",
        "general",
        "block-wise",
        "bf/gen",
        "gen/bw",
    ]);
    for model in FULL_MODELS {
        let costs = cost_graph(model, &crate::profiles::DeviceProfile::jetson_tx2());
        let p = Problem::new(&costs, Link::symmetric(1e6));
        let bf = brute_force_complexity(&p);
        let gen = general_partition_instrumented(&p);
        let bw = blockwise_partition_instrumented(&p);
        t.row(&[
            model.to_string(),
            costs.len().to_string(),
            format!("{bf:.2e}"),
            format!("{:.2e}", gen.complexity),
            format!("{:.2e}", bw.complexity),
            format!("{:.1e}", bf / gen.complexity),
            format!("{:.1}x", gen.complexity / bw.complexity),
        ]);
    }
    format!("Fig 8: computational complexity, full AI models\n{}", t.render())
}

#[cfg(test)]
mod tests {
    #[test]
    fn blockwise_always_cheaper_than_general() {
        let out = super::run();
        assert!(out.contains("densenet121"));
        // Every gen/bw ratio > 1 (last column ends with 'x').
        for line in out.lines().skip(3) {
            let cells: Vec<&str> = line.split_whitespace().collect();
            if cells.len() == 7 {
                let r: f64 = cells[6].trim_end_matches('x').parse().unwrap();
                assert!(r >= 1.0, "{line}");
            }
        }
    }
}
