//! Table I: algorithm running time vs per-iteration training delay — the
//! decision overhead must be negligible against the training it optimizes.

use super::common::{cost_graph, time_median};
use crate::models::FULL_MODELS;
use crate::partition::blockwise::Planner;
use crate::partition::{
    blockwise_partition, general_partition, FleetPlanner, FleetSpec, JointPlanner, Link, Problem,
};
use crate::profiles::DeviceProfile;
use crate::util::table::Table;

/// Devices in the fleet-epoch column (4 deduplicated Jetson tiers).
const FLEET_DEVICES: usize = 100;

/// Shared server capacity of the joint-epoch column: well below the fleet
/// size, so every epoch runs the congestion price loop.
const JOINT_CAPACITY: f64 = 8.0;

pub fn run(reps: usize) -> String {
    let mut t = Table::new(&[
        "model",
        "general (s)",
        "block-wise (s)",
        "warm replan (s)",
        "fleet-100 epoch (s)",
        "joint-100 epoch (s)",
        "train delay/iter (s)",
        "ratio (delay/decision)",
    ]);
    for model in FULL_MODELS {
        let costs = cost_graph(model, &DeviceProfile::jetson_tx2());
        let p = Problem::new(&costs, Link::symmetric(1e6));
        let gen = time_median(reps, || {
            std::hint::black_box(general_partition(&p));
        });
        let bw = time_median(reps, || {
            std::hint::black_box(blockwise_partition(&p));
        });
        // The amortized per-epoch decision: planner built once, warm
        // re-solves thereafter (the coordinator's actual hot path).
        let mut planner = Planner::new(&costs);
        let warm = time_median(reps, || {
            std::hint::black_box(planner.partition(Link::symmetric(1e6)));
        });
        // Fleet-scale epoch decision: one FleetPlanner::plan call covering
        // a 100-device fleet (per-tier links, varied per rep so every tier
        // is dirty each epoch — the worst case).
        let devices = DeviceProfile::fleet_of(FLEET_DEVICES);
        let mut fleet = FleetPlanner::new(FleetSpec::from_fleet(&devices, |d| {
            cost_graph(model, d)
        }));
        let mut epoch = 0u64;
        let fleet_epoch = time_median(reps, || {
            epoch += 1;
            let requests = fleet
                .spec()
                .requests(|tier| Link::symmetric(1e6 * (1.0 + (epoch + tier as u64) as f64)));
            std::hint::black_box(fleet.plan(&requests));
        });
        // Joint shared-server epoch: the same 100-device fleet coupled
        // through a finite server capacity — each epoch pays the makespan
        // bisection × warm price probes on top of the λ=1 pass.
        let mut joint = JointPlanner::with_capacity(
            FleetSpec::from_fleet(&devices, |d| cost_graph(model, d)),
            JOINT_CAPACITY,
        );
        let mut joint_e = 0u64;
        let joint_epoch = time_median(reps, || {
            joint_e += 1;
            let requests = joint
                .spec()
                .requests(|tier| Link::symmetric(1e6 * (1.0 + (joint_e + tier as u64) as f64)));
            std::hint::black_box(joint.plan(&requests));
        });
        // Per-iteration training delay: Eq. (7) for the optimal partition,
        // divided by N_loc local iterations.
        let part = blockwise_partition(&p);
        let per_iter = part.delay / costs.n_loc;
        t.row(&[
            model.to_string(),
            format!("{gen:.2e}"),
            format!("{bw:.2e}"),
            format!("{warm:.2e}"),
            format!("{fleet_epoch:.2e}"),
            format!("{joint_epoch:.2e}"),
            format!("{per_iter:.2}"),
            format!("{:.1e}", per_iter / bw.max(1e-12)),
        ]);
    }
    format!(
        "Table I: running time vs training delay per iteration ({reps} reps)\n{}\n\
         (decision time is {} orders of magnitude below the training delay;\n\
          the fleet column is one batched epoch decision for {FLEET_DEVICES} devices,\n\
          the joint column the same epoch coupled through a shared server of\n\
          capacity {JOINT_CAPACITY} device-equivalents)\n",
        t.render(),
        "several"
    )
}

#[cfg(test)]
mod tests {
    #[test]
    fn decision_is_negligible() {
        let out = super::run(3);
        assert!(out.contains("resnet50"));
    }

    #[test]
    fn decision_time_is_sub_10ms_release_scale() {
        // Even in debug builds the block-wise decision should be < 100 ms
        // for every full model (paper: sub-millisecond on release).
        use super::*;
        use crate::util::fmt_secs;
        for model in FULL_MODELS {
            let costs = cost_graph(model, &crate::profiles::DeviceProfile::jetson_tx2());
            let p = Problem::new(&costs, Link::symmetric(1e6));
            let bw = time_median(3, || {
                std::hint::black_box(blockwise_partition(&p));
            });
            assert!(bw < 0.1, "{model}: {}", fmt_secs(bw));
        }
    }
}
