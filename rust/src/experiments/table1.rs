//! Table I: algorithm running time vs per-iteration training delay — the
//! decision overhead must be negligible against the training it optimizes.

use super::common::{cost_graph, time_median};
use crate::models::FULL_MODELS;
use crate::partition::blockwise::Planner;
use crate::partition::{blockwise_partition, general_partition, Link, Problem};
use crate::util::table::Table;

pub fn run(reps: usize) -> String {
    let mut t = Table::new(&[
        "model",
        "general (s)",
        "block-wise (s)",
        "warm replan (s)",
        "train delay/iter (s)",
        "ratio (delay/decision)",
    ]);
    for model in FULL_MODELS {
        let costs = cost_graph(model, &crate::profiles::DeviceProfile::jetson_tx2());
        let p = Problem::new(&costs, Link::symmetric(1e6));
        let gen = time_median(reps, || {
            std::hint::black_box(general_partition(&p));
        });
        let bw = time_median(reps, || {
            std::hint::black_box(blockwise_partition(&p));
        });
        // The amortized per-epoch decision: planner built once, warm
        // re-solves thereafter (the coordinator's actual hot path).
        let mut planner = Planner::new(&costs);
        let warm = time_median(reps, || {
            std::hint::black_box(planner.partition(Link::symmetric(1e6)));
        });
        // Per-iteration training delay: Eq. (7) for the optimal partition,
        // divided by N_loc local iterations.
        let part = blockwise_partition(&p);
        let per_iter = part.delay / costs.n_loc;
        t.row(&[
            model.to_string(),
            format!("{gen:.2e}"),
            format!("{bw:.2e}"),
            format!("{warm:.2e}"),
            format!("{per_iter:.2}"),
            format!("{:.1e}", per_iter / bw.max(1e-12)),
        ]);
    }
    format!(
        "Table I: running time vs training delay per iteration ({reps} reps)\n{}\n\
         (decision time is {} orders of magnitude below the training delay)\n",
        t.render(),
        "several"
    )
}

#[cfg(test)]
mod tests {
    #[test]
    fn decision_is_negligible() {
        let out = super::run(3);
        assert!(out.contains("resnet50"));
    }

    #[test]
    fn decision_time_is_sub_10ms_release_scale() {
        // Even in debug builds the block-wise decision should be < 100 ms
        // for every full model (paper: sub-millisecond on release).
        use super::*;
        use crate::util::fmt_secs;
        for model in FULL_MODELS {
            let costs = cost_graph(model, &crate::profiles::DeviceProfile::jetson_tx2());
            let p = Problem::new(&costs, Link::symmetric(1e6));
            let bw = time_median(3, || {
                std::hint::black_box(blockwise_partition(&p));
            });
            assert!(bw < 0.1, "{model}: {}", fmt_secs(bw));
        }
    }
}
