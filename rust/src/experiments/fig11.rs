//! Fig. 11: training delay per epoch under large-scale path loss, for both
//! bands (sub-6 GHz / mmWave) and all three channel conditions, comparing
//! the proposed solution with OSS / device-only / regression.

use crate::net::{Band, ChannelCondition, NetConfig};
use crate::sim::{SimConfig, Trainer};
use crate::util::table::Table;

const METHODS: &[&str] = &["proposed", "oss", "device-only", "regression"];

pub fn run(epochs: usize) -> String {
    let mut out = String::new();
    for band in [Band::n1(), Band::n257()] {
        let mut t = Table::new(&["condition", "proposed", "oss", "device-only", "regression", "best-gain"]);
        for cond in ChannelCondition::all() {
            let mut means = Vec::new();
            for method in METHODS {
                let cfg = SimConfig {
                    model: "googlenet".into(),
                    net: NetConfig {
                        band,
                        condition: cond,
                        rayleigh: false,
                        ..NetConfig::default()
                    },
                    method: method.to_string(),
                    seed: 11,
                    ..SimConfig::default()
                };
                let mut trainer = Trainer::new(cfg);
                means.push(trainer.run_epochs(epochs).mean_epoch_delay);
            }
            let proposed = means[0];
            let best_baseline = means[1..].iter().cloned().fold(f64::INFINITY, f64::min);
            let gain = 100.0 * (1.0 - proposed / best_baseline);
            t.row(&[
                cond.name().to_string(),
                format!("{:.1}", means[0]),
                format!("{:.1}", means[1]),
                format!("{:.1}", means[2]),
                format!("{:.1}", means[3]),
                format!("{gain:.1}%"),
            ]);
        }
        out.push_str(&format!(
            "Fig 11 [{}]: mean training delay per epoch (s), GoogLeNet, {} epochs\n{}\n",
            band.name,
            epochs,
            t.render()
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    #[test]
    fn proposed_wins_somewhere() {
        let out = super::run(8);
        assert!(out.contains("n257"));
        assert!(out.contains("normal"));
    }
}
