//! Fig. 15: robustness to network size — training delay with 10 and 40
//! devices (GoogLeNet, non-IID CIFAR-10, mmWave).

use crate::net::{Band, ChannelCondition, NetConfig};
use crate::sim::{Dataset, SimConfig, Trainer};
use crate::util::table::Table;

const METHODS: &[&str] = &["proposed", "oss", "device-only", "regression"];

pub fn run(epochs: usize) -> String {
    let mut out = String::new();
    for devices in [10usize, 40] {
        let mut t = Table::new(&["method", "delay/epoch (s)", "total (min)", "vs proposed"]);
        let mut proposed = 0.0;
        for method in METHODS {
            let cfg = SimConfig {
                model: "googlenet".into(),
                net: NetConfig {
                    band: Band::n257(),
                    condition: ChannelCondition::Normal,
                    num_devices: devices,
                    ..NetConfig::default()
                },
                method: method.to_string(),
                seed: 61,
                ..SimConfig::default()
            };
            let mut trainer = Trainer::new(cfg);
            // Epoch count follows the non-IID CIFAR-10 curve; delays are
            // what varies with the method.
            let _ = Dataset::Cifar10;
            let res = trainer.run_epochs(epochs);
            if *method == "proposed" {
                proposed = res.mean_epoch_delay;
            }
            t.row(&[
                method.to_string(),
                format!("{:.1}", res.mean_epoch_delay),
                format!("{:.1}", res.total_delay / 60.0),
                format!("{:.2}x", res.mean_epoch_delay / proposed.max(1e-9)),
            ]);
        }
        out.push_str(&format!(
            "Fig 15 [{} devices]: GoogLeNet non-IID CIFAR-10, mmWave ({} epochs)\n{}\n",
            devices,
            epochs,
            t.render()
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    #[test]
    fn covers_both_fleet_sizes() {
        let out = super::run(6);
        assert!(out.contains("[10 devices]"));
        assert!(out.contains("[40 devices]"));
    }
}
