//! Fig. 9: measured running time of the partitioning algorithms —
//! (a) single-block networks (including brute force), (b) full models.

use super::common::{cost_graph, time_median};
use crate::models::{BLOCK_NETS, FULL_MODELS};
use crate::partition::baselines::{brute_force_partition, regression_partition};
use crate::partition::{blockwise_partition, general_partition, Link, Problem};
use crate::util::fmt_secs;
use crate::util::table::Table;

/// Fig. 9(a): block networks, all four methods.
pub fn run_blocknets(reps: usize) -> String {
    let mut t = Table::new(&[
        "network",
        "brute-force",
        "general",
        "block-wise",
        "regression",
        "bf/gen",
        "gen/bw",
    ]);
    for net in BLOCK_NETS {
        let costs = cost_graph(net, &crate::profiles::DeviceProfile::jetson_tx2());
        let link = Link::symmetric(1e6);
        let p = Problem::new(&costs, link);
        let bf = time_median(reps.min(30), || {
            std::hint::black_box(brute_force_partition(&p));
        });
        let gen = time_median(reps, || {
            std::hint::black_box(general_partition(&p));
        });
        let bw = time_median(reps, || {
            std::hint::black_box(blockwise_partition(&p));
        });
        let reg = time_median(reps, || {
            std::hint::black_box(regression_partition(&p));
        });
        t.row(&[
            net.to_string(),
            fmt_secs(bf),
            fmt_secs(gen),
            fmt_secs(bw),
            fmt_secs(reg),
            format!("{:.1}x", bf / gen),
            format!("{:.1}x", gen / bw),
        ]);
    }
    format!("Fig 9(a): running time, block networks ({reps} reps median)\n{}", t.render())
}

/// Fig. 9(b): full models, proposed algorithms + regression.
pub fn run_full_models(reps: usize) -> String {
    let mut t = Table::new(&[
        "model",
        "general",
        "block-wise",
        "regression",
        "gen/bw",
    ]);
    for model in FULL_MODELS {
        let costs = cost_graph(model, &crate::profiles::DeviceProfile::jetson_tx2());
        let p = Problem::new(&costs, Link::symmetric(1e6));
        let gen = time_median(reps, || {
            std::hint::black_box(general_partition(&p));
        });
        let bw = time_median(reps, || {
            std::hint::black_box(blockwise_partition(&p));
        });
        let reg = time_median(reps, || {
            std::hint::black_box(regression_partition(&p));
        });
        t.row(&[
            model.to_string(),
            fmt_secs(gen),
            fmt_secs(bw),
            fmt_secs(reg),
            format!("{:.1}x", gen / bw),
        ]);
    }
    format!("Fig 9(b): running time, full AI models ({reps} reps median)\n{}", t.render())
}

#[cfg(test)]
mod tests {
    #[test]
    fn blocknet_timing_runs() {
        let out = super::run_blocknets(3);
        assert!(out.contains("block-inception"));
    }
}
