//! Table II: overall training delay across the four full models x
//! {CIFAR-10, CIFAR-100} x {IID, non-IID}, with the paper's bold
//! baseline/proposed ratios.

use crate::models::FULL_MODELS;
use crate::net::{Band, ChannelCondition, NetConfig};
use crate::sim::{Dataset, SimConfig, Trainer};
use crate::util::table::Table;

const METHODS: &[&str] = &["oss", "device-only", "regression", "proposed"];

pub fn run(runs: usize) -> String {
    let mut t = Table::new(&[
        "model",
        "method",
        "c10-iid",
        "c10-noniid",
        "c100-iid",
        "c100-noniid",
    ]);
    for model in FULL_MODELS {
        // Collect proposed last row first for ratio annotation.
        let mut rows: Vec<(String, Vec<f64>)> = Vec::new();
        for method in METHODS {
            let mut cells = Vec::new();
            for (dataset, iid) in [
                (Dataset::Cifar10, true),
                (Dataset::Cifar10, false),
                (Dataset::Cifar100, true),
                (Dataset::Cifar100, false),
            ] {
                let mut total = 0.0;
                for run in 0..runs {
                    let cfg = SimConfig {
                        model: model.to_string(),
                        net: NetConfig {
                            band: Band::n257(),
                            condition: ChannelCondition::Normal,
                            ..NetConfig::default()
                        },
                        method: method.to_string(),
                        seed: 41 + run as u64,
                        ..SimConfig::default()
                    };
                    let mut trainer = Trainer::new(cfg);
                    let (res, _) = trainer.run_to_accuracy(dataset, iid, 5000);
                    total += res.total_delay;
                }
                cells.push(total / runs as f64 / 60.0); // minutes
            }
            rows.push((method.to_string(), cells));
        }
        let proposed = rows.last().unwrap().1.clone();
        for (method, cells) in rows {
            let fmt = |i: usize| {
                if method == "proposed" {
                    format!("{:.0}", cells[i])
                } else {
                    format!("{:.0} ({:.2}x)", cells[i], cells[i] / proposed[i])
                }
            };
            t.row(&[
                model.to_string(),
                method.clone(),
                fmt(0),
                fmt(1),
                fmt(2),
                fmt(3),
            ]);
        }
    }
    format!(
        "Table II: overall training delay (minutes) to accuracy threshold ({runs} runs)\n{}",
        t.render()
    )
}

#[cfg(test)]
mod tests {
    #[test]
    fn table_covers_all_models() {
        // One run, one model subset would still print; full check is the
        // harness itself (slow), so just smoke the formatting path on the
        // smallest model via the public entry is too slow for unit tests —
        // formatting is covered by other harness tests.
        assert!(super::METHODS.contains(&"proposed"));
    }
}
