//! Experiment harnesses regenerating every table and figure of the paper's
//! evaluation (Sec. VII). Each harness prints the same rows/series the
//! paper reports; EXPERIMENTS.md records paper-vs-measured.
//!
//! Run via `fastsplit experiment --id <id>` (`--quick` shrinks repetition
//! counts for smoke runs). `--id all` runs everything.

pub mod common;
pub mod fig7;
pub mod fig8;
pub mod fig9;
pub mod table1;
pub mod fig11;
pub mod fig12;
pub mod fig13;
pub mod table2;
pub mod fig14;
pub mod fig15;
pub mod fig16;
pub mod ablations;
pub mod topology;

/// All experiment ids in paper order (the `topo*` ids are the PR-10
/// multi-hop / multi-server sweeps beyond the paper).
pub const ALL_IDS: &[&str] = &[
    "fig7a", "fig7b", "fig8", "fig9a", "fig9b", "tab1", "fig11", "fig12", "fig13", "tab2",
    "fig14", "fig15", "fig16", "ablA", "ablB", "topoA", "topoB",
];

/// Run one experiment by id, returning its printable report.
pub fn run(id: &str, quick: bool) -> Option<String> {
    let out = match id {
        "fig7a" => fig7::run_complexity(),
        "fig7b" => fig7::run_optimality(if quick { 100 } else { 1000 }),
        "fig8" => fig8::run(),
        "fig9a" => fig9::run_blocknets(if quick { 50 } else { 1000 }),
        "fig9b" => fig9::run_full_models(if quick { 20 } else { 1000 }),
        "tab1" => table1::run(if quick { 20 } else { 200 }),
        "fig11" => fig11::run(if quick { 20 } else { 300 }),
        "fig12" => fig12::run(if quick { 30 } else { 120 }),
        "fig13" => fig13::run(if quick { 1 } else { 3 }),
        "tab2" => table2::run(if quick { 1 } else { 3 }),
        "fig14" => fig14::run(if quick { 1 } else { 3 }),
        "fig15" => fig15::run(if quick { 20 } else { 100 }),
        "fig16" => fig16::run(),
        "ablA" => ablations::run_closure(if quick { 100 } else { 1000 }),
        "ablB" => ablations::run_solvers(),
        "topoA" => topology::run_paths(if quick { 5 } else { 40 }),
        "topoB" => topology::run_servers(if quick { 3 } else { 20 }),
        _ => return None,
    };
    Some(out)
}
