//! Fig. 14: GPT-2 on the CARER dataset (non-IID) over the mmWave network —
//! the paper's LLM extension (Sec. VI-E), partitioned block-wise with the
//! embedding / transformer blocks / head treated as blocks.

use crate::net::{Band, ChannelCondition, NetConfig};
use crate::sim::{Dataset, SimConfig, Trainer};
use crate::util::table::Table;

const METHODS: &[&str] = &["proposed", "oss", "regression", "device-only"];

pub fn run(runs: usize) -> String {
    let mut t = Table::new(&["method", "delay (min)", "reduction vs method"]);
    let mut delays = Vec::new();
    for method in METHODS {
        let mut total = 0.0;
        for run in 0..runs {
            let cfg = SimConfig {
                model: "gpt2".into(),
                net: NetConfig {
                    band: Band::n257(),
                    condition: ChannelCondition::Normal,
                    ..NetConfig::default()
                },
                method: method.to_string(),
                seed: 51 + run as u64,
                ..SimConfig::default()
            };
            let mut trainer = Trainer::new(cfg);
            let (res, _) = trainer.run_to_accuracy(Dataset::Carer, false, 5000);
            total += res.total_delay;
        }
        delays.push(total / runs as f64 / 60.0);
    }
    let proposed = delays[0];
    for (method, d) in METHODS.iter().zip(&delays) {
        let red = 100.0 * (1.0 - proposed / d);
        t.row(&[
            method.to_string(),
            format!("{d:.0}"),
            if *method == "proposed" {
                "-".into()
            } else {
                format!("{red:.1}%")
            },
        ]);
    }
    format!(
        "Fig 14: GPT-2 on CARER (non-IID, mmWave normal, {runs} runs)\n{}",
        t.render()
    )
}

#[cfg(test)]
mod tests {
    use crate::models;
    use crate::partition::blockwise::blockwise_partition_instrumented;
    use crate::partition::{Link, Problem};
    use crate::profiles::{CostGraph, DeviceProfile, TrainCfg};

    #[test]
    fn gpt2_blocks_abstract_cleanly() {
        // The Sec. VI-E claim: GPT-2's transformer blocks behave as blocks.
        let m = models::by_name("gpt2").unwrap();
        let c = CostGraph::build(
            &m,
            &DeviceProfile::jetson_agx_orin(),
            &DeviceProfile::rtx_a6000(),
            &TrainCfg::default(),
        );
        let p = Problem::new(&c, Link::symmetric(1e7));
        let run = blockwise_partition_instrumented(&p);
        assert!(run.blocks_abstracted >= 12, "{}", run.blocks_abstracted);
        assert!(run.flow_vertices < c.len());
    }
}
