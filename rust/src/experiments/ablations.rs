//! Ablations beyond the paper (DESIGN.md ablA/ablB):
//!
//! * **ablA** — closure (precedence) edges: the paper's construction omits
//!   them, relying on Assumption 1 for feasibility. We quantify how often
//!   omitting them changes the result (a) under Assumption 1 and (b) when
//!   it is violated (heterogeneous fleets where a device beats the server
//!   on some layers).
//! * **ablB** — max-flow solver: Dinic (paper's choice) vs push-relabel on
//!   the partition DAGs of every zoo model.

use super::common::{cost_graph, time_median};
use crate::maxflow::{dinic, push_relabel, FlowNetwork};
use crate::models::MODEL_NAMES;
use crate::partition::baselines::brute_force_partition;
use crate::partition::general::general_partition_with_options;
use crate::partition::{Link, Problem};
use crate::profiles::CostGraph;
use crate::util::fmt_secs;
use crate::util::prop::random_layer_dag;
use crate::util::rng::Rng;
use crate::util::table::Table;

/// ablA: closure-edge ablation over random DAG problems.
pub fn run_closure(runs: usize) -> String {
    let mut t = Table::new(&[
        "regime",
        "runs",
        "no-closure optimal",
        "no-closure infeasible",
        "with-closure optimal",
    ]);
    let mut rng = Rng::new(0xAB1A);
    for violate_a1 in [false, true] {
        let mut optimal_no = 0usize;
        let mut infeasible_no = 0usize;
        let mut optimal_with = 0usize;
        for _ in 0..runs {
            let c = random_problem(&mut rng, violate_a1);
            let link = Link {
                up_bps: rng.range(1e4, 1e8),
                down_bps: rng.range(1e4, 1e8),
            };
            let p = Problem::new(&c, link);
            let best = brute_force_partition(&p);
            let tol = 1e-9 * (1.0 + best.delay);

            let no = general_partition_with_options(&p, false).partition;
            if !p.is_feasible(&no.device_set) {
                infeasible_no += 1;
            } else if (no.delay - best.delay).abs() <= tol {
                optimal_no += 1;
            }
            let with = general_partition_with_options(&p, true).partition;
            if (with.delay - best.delay).abs() <= tol {
                optimal_with += 1;
            }
        }
        let pct = |h: usize| format!("{:.1}%", 100.0 * h as f64 / runs as f64);
        t.row(&[
            if violate_a1 {
                "Assumption 1 violated".into()
            } else {
                "Assumption 1 holds".to_string()
            },
            runs.to_string(),
            pct(optimal_no),
            pct(infeasible_no),
            pct(optimal_with),
        ]);
    }
    format!("Ablation A: precedence (closure) edges in the flow network\n{}", t.render())
}

fn random_problem(rng: &mut Rng, violate_a1: bool) -> CostGraph {
    let n = 3 + rng.index(8);
    let edges = random_layer_dag(rng, n, 0.25);
    let mut dag = crate::graph::Dag::new();
    for i in 0..n {
        dag.add_node(format!("v{i}"));
    }
    for (u, v) in edges {
        dag.add_edge(u, v, 0.0);
    }
    let xi_s: Vec<f64> = (0..n).map(|_| rng.range(1e-4, 5e-2)).collect();
    let xi_d: Vec<f64> = xi_s
        .iter()
        .map(|&s| {
            if violate_a1 && rng.chance(0.4) {
                s * rng.range(0.05, 1.0)
            } else {
                s * rng.range(1.0, 20.0)
            }
        })
        .collect();
    CostGraph {
        dag,
        xi_d,
        xi_s,
        act_bytes: (0..n).map(|_| rng.range(1e3, 1e7)).collect(),
        param_bytes: (0..n).map(|_| rng.range(0.0, 1e6)).collect(),
        n_loc: 10.0,
    }
}

/// ablB: Dinic vs push-relabel on every zoo model's partition network.
pub fn run_solvers() -> String {
    let mut t = Table::new(&["model", "dinic", "push-relabel", "values match"]);
    for model in MODEL_NAMES {
        let costs = cost_graph(model, &crate::profiles::DeviceProfile::jetson_tx2());
        let n = costs.len();
        let build = || {
            // Plain Alg.1-style network (no aux, solver comparison only).
            let mut net = FlowNetwork::new(n + 2);
            let (s, t) = (n, n + 1);
            let link = Link::symmetric(1e6);
            for v in 0..n {
                net.add_edge(s, v, costs.n_loc * costs.xi_s[v]);
                net.add_edge(
                    v,
                    t,
                    costs.n_loc * costs.xi_d[v] + costs.param_bytes[v] * link.sigma(),
                );
            }
            for e in costs.dag.edges() {
                let w = costs.n_loc * costs.act_bytes[e.from] * link.sigma();
                net.add_edge(e.from, e.to, w);
            }
            net
        };
        let d_time = time_median(9, || {
            let mut net = build();
            std::hint::black_box(dinic(&mut net, n, n + 1));
        });
        let p_time = time_median(9, || {
            let mut net = build();
            std::hint::black_box(push_relabel(&mut net, n, n + 1));
        });
        let dv = dinic(&mut build(), n, n + 1).value;
        let pv = push_relabel(&mut build(), n, n + 1).value;
        let matches = (dv - pv).abs() <= 1e-6 * (1.0 + dv.abs());
        t.row(&[
            model.to_string(),
            fmt_secs(d_time),
            fmt_secs(p_time),
            matches.to_string(),
        ]);
    }
    format!("Ablation B: max-flow solver comparison (same network, median of 9)\n{}", t.render())
}

#[cfg(test)]
mod tests {
    #[test]
    fn closure_ablation_reports_full_optimality_with_closure() {
        let out = super::run_closure(60);
        for line in out.lines() {
            if line.starts_with("Assumption") {
                let last = line.split_whitespace().last().unwrap();
                assert_eq!(last, "100.0%", "{line}");
            }
        }
    }

    #[test]
    fn solvers_agree_on_all_models() {
        let out = super::run_solvers();
        assert!(!out.contains("false"), "{out}");
    }
}
