//! Shared helpers for the experiment harnesses.

use crate::models;
use crate::partition::{Link, Problem};
use crate::profiles::{CostGraph, DeviceProfile, TrainCfg};
use crate::util::rng::Rng;
use std::time::Instant;

/// Build the cost graph for a zoo model on a given device tier.
pub fn cost_graph(model: &str, device: &DeviceProfile) -> CostGraph {
    let m = models::by_name(model).unwrap_or_else(|| panic!("unknown model {model}"));
    CostGraph::build(&m, device, &DeviceProfile::rtx_a6000(), &TrainCfg::default())
}

/// A randomized evaluation context (device tier + link rates), as the
/// paper's 1000-run averages randomize device and channel conditions.
pub fn random_context(rng: &mut Rng) -> (DeviceProfile, Link) {
    let tiers = [
        DeviceProfile::jetson_tx1(),
        DeviceProfile::jetson_tx2(),
        DeviceProfile::jetson_orin_nano(),
        DeviceProfile::jetson_agx_orin(),
    ];
    let device = tiers[rng.index(4)].clone();
    // Log-uniform rates across the CQI-reachable range (bytes/s).
    let log_lo = 4.0; // 10 kB/s
    let log_hi = 8.5; // ~300 MB/s
    let up = 10f64.powf(rng.range(log_lo, log_hi));
    let down = up * rng.range(1.0, 8.0);
    (device, Link { up_bps: up, down_bps: down })
}

/// Median wall-clock seconds of `f` over `reps` runs (dropping the first,
/// which may include lazy allocations).
pub fn time_median<F: FnMut()>(reps: usize, mut f: F) -> f64 {
    f();
    let mut samples: Vec<f64> = (0..reps.max(1))
        .map(|_| {
            let t = Instant::now();
            f();
            t.elapsed().as_secs_f64()
        })
        .collect();
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    samples[samples.len() / 2]
}

/// Problem wrapper for one-off evaluations.
pub fn problem<'a>(costs: &'a CostGraph, link: Link) -> Problem<'a> {
    Problem::new(costs, link)
}

/// Format a ratio like the paper's "(1.33x)" annotations.
pub fn ratio(x: f64, base: f64) -> String {
    format!("{:.2}x", x / base)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_context_in_range() {
        let mut rng = Rng::new(5);
        for _ in 0..100 {
            let (_, link) = random_context(&mut rng);
            assert!(link.up_bps >= 1e4 && link.up_bps <= 10f64.powf(8.5));
            assert!(link.down_bps >= link.up_bps);
        }
    }

    #[test]
    fn time_median_positive() {
        let t = time_median(5, || {
            std::hint::black_box((0..1000u64).sum::<u64>());
        });
        assert!(t >= 0.0);
    }
}
