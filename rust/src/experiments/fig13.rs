//! Fig. 13: overall training delay to reach the accuracy threshold when
//! training GoogLeNet on CIFAR-10, IID vs non-IID, against four baselines
//! (central runs everything on the server).

use crate::net::{Band, ChannelCondition, NetConfig};
use crate::sim::{Dataset, SimConfig, Trainer};
use crate::util::table::Table;

const METHODS: &[&str] = &["proposed", "oss", "device-only", "regression", "central"];

pub fn run(runs: usize) -> String {
    let mut out = String::new();
    for iid in [true, false] {
        let mut t = Table::new(&["method", "delay (min)", "epochs", "vs proposed"]);
        let mut proposed_delay = 0.0;
        for method in METHODS {
            let mut total = 0.0;
            let mut epochs_sum = 0usize;
            for run in 0..runs {
                let cfg = SimConfig {
                    model: "googlenet".into(),
                    net: NetConfig {
                        band: Band::n257(),
                        condition: ChannelCondition::Normal,
                        ..NetConfig::default()
                    },
                    method: method.to_string(),
                    seed: 31 + run as u64,
                    ..SimConfig::default()
                };
                let mut trainer = Trainer::new(cfg);
                let (res, epochs) = trainer.run_to_accuracy(Dataset::Cifar10, iid, 5000);
                total += res.total_delay;
                epochs_sum += epochs;
            }
            let mean_min = total / runs as f64 / 60.0;
            if *method == "proposed" {
                proposed_delay = mean_min;
            }
            t.row(&[
                method.to_string(),
                format!("{mean_min:.1}"),
                format!("{}", epochs_sum / runs),
                format!("{:.2}x", mean_min / proposed_delay.max(1e-9)),
            ]);
        }
        out.push_str(&format!(
            "Fig 13 [{}]: GoogLeNet on CIFAR-10 to {:.0}% accuracy ({} runs)\n{}\n",
            if iid { "IID" } else { "non-IID" },
            Dataset::Cifar10.threshold(iid) * 100.0,
            runs,
            t.render()
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    #[test]
    fn proposed_is_fastest_among_privacy_preserving_methods() {
        let out = super::run(1);
        // "vs proposed" must be >= 1.00x for all SL baselines; `central`
        // (raw data shipped to the server) may undercut it.
        for line in out.lines() {
            if line.starts_with("central") {
                continue;
            }
            if let Some(r) = line.split_whitespace().last() {
                if r.ends_with('x') {
                    let v: f64 = r.trim_end_matches('x').parse().unwrap();
                    assert!(v >= 0.99, "{line}");
                }
            }
        }
    }
}
