//! Topology sweeps beyond the paper (PR 10): multi-hop relay paths and
//! multi-server fleets.
//!
//! * **topoA** — path-length sweep: the `proposed-multihop` simulator
//!   method over relay ladders of 1..4 hops (`partition::multihop`).
//!   One hop is the paper's single device→server split; longer paths
//!   report the K-segment planner's DP/pooling work alongside the
//!   epoch delays.
//! * **topoB** — server-count sweep: the `proposed-multiserver` method
//!   over capacity vectors of 1/2/4 servers at equal total capacity
//!   (`partition::assign`), reporting the assignment search's move and
//!   inner-makespan counters.

use crate::net::NetConfig;
use crate::sim::{SimConfig, Trainer};
use crate::util::fmt_secs;
use crate::util::table::Table;

const MODEL: &str = "googlenet";

/// A 6-device fleet keeps the assignment search enumerable (2 servers →
/// 64 assignments, within the exhaustive cap) and the sweeps snappy.
fn base_cfg(method: &str) -> SimConfig {
    SimConfig {
        model: MODEL.into(),
        net: NetConfig {
            num_devices: 6,
            ..NetConfig::default()
        },
        method: method.into(),
        seed: 17,
        ..SimConfig::default()
    }
}

/// topoA: relay-path length sweep for the multi-hop planner.
pub fn run_paths(epochs: usize) -> String {
    let mut t = Table::new(&[
        "hops",
        "mean epoch delay",
        "mean decision",
        "dp transitions",
        "plans",
    ]);
    for hops in 1..=4usize {
        let mut cfg = base_cfg("proposed-multihop");
        cfg.path_hops = hops;
        let mut trainer = Trainer::new(cfg);
        let r = trainer.run_epochs(epochs);
        let s = trainer.planner_stats();
        t.row(&[
            hops.to_string(),
            fmt_secs(r.mean_epoch_delay),
            fmt_secs(r.mean_decision_time),
            s.dp_transitions.to_string(),
            s.plans.to_string(),
        ]);
    }
    format!(
        "Topology A: K-segment splits over relay paths ({MODEL}, {epochs} epochs; \
         1 hop = the paper's single split)\n{}",
        t.render()
    )
}

/// topoB: server-count sweep at equal total capacity for the
/// device→server assignment planner.
pub fn run_servers(epochs: usize) -> String {
    let total = 0.8;
    let mut t = Table::new(&[
        "servers",
        "capacity each",
        "mean epoch delay",
        "mean decision",
        "assignment moves",
        "inner solves",
    ]);
    for servers in [1usize, 2, 4] {
        let each = total / servers as f64;
        let mut cfg = base_cfg("proposed-multiserver");
        cfg.server_capacities = vec![each; servers];
        let mut trainer = Trainer::new(cfg);
        let r = trainer.run_epochs(epochs);
        let s = trainer.planner_stats();
        t.row(&[
            servers.to_string(),
            format!("{each:.2}"),
            fmt_secs(r.mean_epoch_delay),
            fmt_secs(r.mean_decision_time),
            s.assignment_moves.to_string(),
            s.inner_makespan_solves.to_string(),
        ]);
    }
    format!(
        "Topology B: device→server assignment at equal total capacity \
         ({MODEL}, {epochs} epochs, total capacity {total})\n{}",
        t.render()
    )
}

#[cfg(test)]
mod tests {
    #[test]
    fn path_sweep_renders_all_hop_counts() {
        let out = super::run_paths(3);
        assert!(out.contains("hops"), "{out}");
        // One row per hop count, 1..=4.
        for hops in 1..=4 {
            assert!(
                out.lines().any(|l| l.trim().starts_with(&hops.to_string())),
                "missing row for {hops} hops:\n{out}"
            );
        }
    }

    #[test]
    fn server_sweep_renders_and_counts_inner_solves() {
        let out = super::run_servers(2);
        assert!(out.contains("servers"), "{out}");
        // One row per server count; the 1-server row is the verbatim
        // JointPlanner delegation (no assignment search, counter 0),
        // every multi-server row must have scored candidates.
        for servers in [1usize, 2, 4] {
            let row = out
                .lines()
                .find(|l| l.starts_with(&servers.to_string()))
                .unwrap_or_else(|| panic!("missing row for {servers} servers:\n{out}"));
            let inner: u64 = row
                .split_whitespace()
                .last()
                .unwrap()
                .parse()
                .unwrap_or_else(|_| panic!("bad inner-solves cell: {row}"));
            if servers == 1 {
                assert_eq!(inner, 0, "1 server must delegate, not search: {row}");
            } else {
                assert!(inner > 0, "no inner makespan solves: {row}");
            }
        }
    }
}
