//! Fig. 7: (a) computational complexity and (b) probability of the optimal
//! cut, on the three single-block networks of Fig. 6.

use super::common::{cost_graph, random_context};
use crate::models::BLOCK_NETS;
use crate::partition::baselines::{
    brute_force_complexity, brute_force_partition, regression_partition,
};
use crate::partition::blockwise::blockwise_partition_instrumented;
use crate::partition::general::general_partition_instrumented;
use crate::partition::Problem;
use crate::util::rng::Rng;
use crate::util::table::Table;

/// Fig. 7(a): theoretical operation counts per algorithm and block net.
pub fn run_complexity() -> String {
    let mut t = Table::new(&[
        "network",
        "brute-force",
        "general",
        "block-wise",
        "bf/gen",
        "gen/bw",
    ]);
    for net in BLOCK_NETS {
        let costs = cost_graph(net, &crate::profiles::DeviceProfile::jetson_tx2());
        let p = Problem::new(&costs, crate::partition::Link::symmetric(1e6));
        let bf = brute_force_complexity(&p);
        let gen = general_partition_instrumented(&p).complexity;
        let bw = blockwise_partition_instrumented(&p).complexity;
        t.row(&[
            net.to_string(),
            format!("{bf:.2e}"),
            format!("{gen:.2e}"),
            format!("{bw:.2e}"),
            format!("{:.1}x", bf / gen),
            format!("{:.1}x", gen / bw),
        ]);
    }
    format!("Fig 7(a): computational complexity (operation counts)\n{}", t.render())
}

/// Fig. 7(b): probability that each method returns the brute-force optimum
/// over `runs` randomized device/link contexts.
pub fn run_optimality(runs: usize) -> String {
    let mut t = Table::new(&["network", "general", "block-wise", "regression"]);
    let mut rng = Rng::new(0x716);
    for net in BLOCK_NETS {
        let mut hits = [0usize; 3];
        for _ in 0..runs {
            let (device, link) = random_context(&mut rng);
            let costs = cost_graph(net, &device);
            let p = Problem::new(&costs, link);
            let best = brute_force_partition(&p);
            let tol = 1e-9 * (1.0 + best.delay);
            let gen = general_partition_instrumented(&p).partition;
            let bw = blockwise_partition_instrumented(&p).partition;
            let reg = regression_partition(&p);
            if (gen.delay - best.delay).abs() <= tol {
                hits[0] += 1;
            }
            if (bw.delay - best.delay).abs() <= tol {
                hits[1] += 1;
            }
            if (reg.delay - best.delay).abs() <= tol {
                hits[2] += 1;
            }
        }
        let pct = |h: usize| format!("{:.1}%", 100.0 * h as f64 / runs as f64);
        t.row(&[net.to_string(), pct(hits[0]), pct(hits[1]), pct(hits[2])]);
    }
    format!(
        "Fig 7(b): probability of the optimal cut over {runs} randomized runs\n{}",
        t.render()
    )
}

#[cfg(test)]
mod tests {
    #[test]
    fn complexity_table_has_ratios() {
        let out = super::run_complexity();
        assert!(out.contains("block-residual"));
        assert!(out.contains('x'));
    }

    #[test]
    fn proposed_methods_always_optimal() {
        let out = super::run_optimality(40);
        // general & block-wise columns must be 100%.
        for line in out.lines().skip(3) {
            if line.starts_with("block-") {
                let cells: Vec<&str> = line.split_whitespace().collect();
                assert_eq!(cells[1], "100.0%", "{line}");
                assert_eq!(cells[2], "100.0%", "{line}");
            }
        }
    }
}
