//! Fig. 16: delay decomposition — device compute / server compute /
//! transmission for two joint iterations of GoogLeNet over mmWave at
//! batch 32, per method.

use crate::net::{Band, ChannelCondition, NetConfig};
use crate::partition::baselines::partition_by_method;
use crate::partition::Problem;
use crate::profiles::{CostGraph, DeviceProfile, TrainCfg};
use crate::sim::DelayBreakdown;
use crate::util::table::Table;

pub fn run() -> String {
    // Two iterations (n_loc = 2), batch 32, as the paper specifies.
    let cfg = TrainCfg {
        batch: 32,
        n_loc: 2,
        bwd_ratio: 2.0,
    };
    let model = crate::models::by_name("googlenet").unwrap();
    let costs = CostGraph::build(
        &model,
        &DeviceProfile::jetson_tx2(),
        &DeviceProfile::rtx_a6000(),
        &cfg,
    );
    let mut net = crate::net::EdgeNetwork::new(NetConfig {
        band: Band::n257(),
        condition: ChannelCondition::Normal,
        ..NetConfig::default()
    });
    let link = net.nominal_link(512);

    let mut t = Table::new(&[
        "method",
        "device-compute (s)",
        "server-compute (s)",
        "transmission (s)",
        "total (s)",
    ]);
    for method in ["proposed", "oss", "regression", "device-only", "central"] {
        let p = Problem::new(&costs, link);
        let part = partition_by_method(method, &p, link);
        let b = DelayBreakdown::of(&p, &part.device_set);
        t.row(&[
            method.to_string(),
            format!("{:.2}", b.device_compute),
            format!("{:.2}", b.server_compute),
            format!("{:.2}", b.transmission()),
            format!("{:.2}", b.total()),
        ]);
    }
    format!(
        "Fig 16: delay decomposition, GoogLeNet mmWave, batch 32, 2 iterations\n{}",
        t.render()
    )
}

#[cfg(test)]
mod tests {
    #[test]
    fn device_only_has_zero_server_compute() {
        let out = super::run();
        let line = out.lines().find(|l| l.starts_with("device-only")).unwrap();
        let cells: Vec<&str> = line.split_whitespace().collect();
        assert_eq!(cells[2], "0.00", "{line}");
    }

    #[test]
    fn central_has_zero_transmission() {
        let out = super::run();
        let line = out.lines().find(|l| l.starts_with("central")).unwrap();
        let cells: Vec<&str> = line.split_whitespace().collect();
        assert_eq!(cells[1], "0.00", "{line}");
        assert_eq!(cells[3], "0.00", "{line}");
    }
}
