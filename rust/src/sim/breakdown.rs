//! Per-component delay decomposition of Eq. (7) — the quantities Fig. 16
//! plots (device compute / server compute / transmission).

use crate::partition::Problem;

/// Decomposed training delay for one epoch under a given partition.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct DelayBreakdown {
    /// N_loc * T_{D,C}: device-side compute.
    pub device_compute: f64,
    /// N_loc * T_{S,C}: server-side compute.
    pub server_compute: f64,
    /// N_loc * (T_{D,S} + T_{S,G}): smashed data up + gradients down.
    pub activation_transfer: f64,
    /// T_{D,U} + T_{S,D}: device-side model upload + download.
    pub model_transfer: f64,
}

impl DelayBreakdown {
    /// Compute the decomposition for a device set (components sum to
    /// [`Problem::delay`]).
    pub fn of(problem: &Problem, device_set: &[bool]) -> DelayBreakdown {
        let c = problem.costs;
        let mut device_compute = 0.0;
        let mut server_compute = 0.0;
        let mut boundary_bytes = 0.0;
        let mut device_param_bytes = 0.0;
        for v in 0..c.len() {
            if device_set[v] {
                device_compute += c.xi_d[v];
                device_param_bytes += c.param_bytes[v];
                if c
                    .dag
                    .out_edges(v)
                    .iter()
                    .any(|&e| !device_set[c.dag.edge(e).to])
                {
                    boundary_bytes += c.act_bytes[v];
                }
            } else {
                server_compute += c.xi_s[v];
            }
        }
        DelayBreakdown {
            device_compute: c.n_loc * device_compute,
            server_compute: c.n_loc * server_compute,
            activation_transfer: c.n_loc
                * (boundary_bytes / problem.link.up_bps + boundary_bytes / problem.link.down_bps),
            model_transfer: device_param_bytes / problem.link.up_bps
                + device_param_bytes / problem.link.down_bps,
        }
    }

    /// Total = Eq. (7).
    pub fn total(&self) -> f64 {
        self.device_compute + self.server_compute + self.activation_transfer + self.model_transfer
    }

    /// All transmission components combined (Fig. 16's third bar).
    pub fn transmission(&self) -> f64 {
        self.activation_transfer + self.model_transfer
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;
    use crate::partition::{blockwise_partition, Link};
    use crate::profiles::{CostGraph, DeviceProfile, TrainCfg};

    #[test]
    fn components_sum_to_delay() {
        let m = models::by_name("googlenet").unwrap();
        let c = CostGraph::build(
            &m,
            &DeviceProfile::jetson_tx2(),
            &DeviceProfile::rtx_a6000(),
            &TrainCfg::default(),
        );
        for rate in [1e5, 1e6, 1e8] {
            let p = Problem::new(&c, Link::symmetric(rate));
            let part = blockwise_partition(&p);
            let b = DelayBreakdown::of(&p, &part.device_set);
            assert!(
                (b.total() - part.delay).abs() < 1e-9 * (1.0 + part.delay),
                "rate={rate}: {} vs {}",
                b.total(),
                part.delay
            );
        }
    }

    #[test]
    fn central_is_pure_server_compute() {
        let m = models::by_name("lenet5").unwrap();
        let c = CostGraph::build(
            &m,
            &DeviceProfile::jetson_tx1(),
            &DeviceProfile::rtx_a6000(),
            &TrainCfg::default(),
        );
        let p = Problem::new(&c, Link::symmetric(1e6));
        let b = DelayBreakdown::of(&p, &vec![false; c.len()]);
        assert_eq!(b.device_compute, 0.0);
        assert_eq!(b.transmission(), 0.0);
        assert!(b.server_compute > 0.0);
    }
}
