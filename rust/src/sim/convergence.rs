//! Epochs-to-accuracy learning curves for the time-to-threshold
//! experiments (Fig. 13-15, Table II, Fig. 14).
//!
//! **Substitution (DESIGN.md):** the paper trains CIFAR-10/100 and CARER on
//! the hardware testbed and reports wall-clock to an accuracy threshold.
//! The quantity under study — training *delay* — is `epochs_to_threshold x
//! delay_per_epoch`; only the second factor depends on the partitioning
//! method. We model the first with a saturating-exponential curve
//! `acc(e) = a_max (1 - exp(-e/tau))` with mild seeded noise, calibrated so
//! epoch counts land in the range implied by the paper's totals (hundreds
//! of epochs). Non-IID data (Dirichlet γ=0.5, Sec. VII-B.3) slows
//! convergence (larger τ) and lowers the asymptote — the standard empirical
//! effect the paper leans on.

use crate::util::rng::Rng;

/// Dataset presets of the evaluation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dataset {
    Cifar10,
    Cifar100,
    Carer,
}

impl Dataset {
    pub fn name(self) -> &'static str {
        match self {
            Dataset::Cifar10 => "cifar10",
            Dataset::Cifar100 => "cifar100",
            Dataset::Carer => "carer",
        }
    }

    pub fn by_name(name: &str) -> Option<Dataset> {
        match name {
            "cifar10" => Some(Dataset::Cifar10),
            "cifar100" => Some(Dataset::Cifar100),
            "carer" => Some(Dataset::Carer),
            _ => None,
        }
    }

    /// The paper's accuracy thresholds (Sec. VII-B.3/4).
    pub fn threshold(self, iid: bool) -> f64 {
        match (self, iid) {
            (Dataset::Cifar10, _) => 0.95,
            (Dataset::Cifar100, true) => 0.79,
            (Dataset::Cifar100, false) => 0.78,
            (Dataset::Carer, _) => 0.90,
        }
    }
}

/// Saturating-exponential accuracy curve with seeded epoch noise.
#[derive(Clone, Debug)]
pub struct LearningCurve {
    /// Asymptotic accuracy.
    pub a_max: f64,
    /// Time constant in epochs.
    pub tau: f64,
    /// Noise amplitude on per-epoch accuracy.
    pub noise: f64,
}

impl LearningCurve {
    /// Calibrated curve per (dataset, iid). Values chosen so that
    /// epochs-to-threshold lands at a few hundred epochs, the range implied
    /// by the paper's total-delay tables, and non-IID needs ~1.3x the
    /// epochs of IID.
    pub fn for_setting(dataset: Dataset, iid: bool) -> LearningCurve {
        let (a_max, tau) = match (dataset, iid) {
            (Dataset::Cifar10, true) => (0.975, 85.0),
            (Dataset::Cifar10, false) => (0.968, 110.0),
            (Dataset::Cifar100, true) => (0.815, 95.0),
            (Dataset::Cifar100, false) => (0.805, 120.0),
            (Dataset::Carer, true) => (0.93, 60.0),
            (Dataset::Carer, false) => (0.925, 80.0),
        };
        LearningCurve {
            a_max,
            tau,
            noise: 0.004,
        }
    }

    /// Accuracy after `epoch` epochs (noise-free mean).
    pub fn mean_accuracy(&self, epoch: f64) -> f64 {
        self.a_max * (1.0 - (-epoch / self.tau).exp())
    }

    /// Accuracy sample for one run at an epoch.
    pub fn accuracy(&self, epoch: f64, rng: &mut Rng) -> f64 {
        (self.mean_accuracy(epoch) + rng.normal(0.0, self.noise)).clamp(0.0, 1.0)
    }

    /// First epoch at which a run's accuracy reaches `threshold`.
    /// Returns `None` if the curve cannot reach it within `max_epochs`.
    pub fn epochs_to_threshold(
        &self,
        threshold: f64,
        max_epochs: usize,
        rng: &mut Rng,
    ) -> Option<usize> {
        for e in 1..=max_epochs {
            if self.accuracy(e as f64, rng) >= threshold {
                return Some(e);
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn curve_is_monotone_and_saturating() {
        let c = LearningCurve::for_setting(Dataset::Cifar10, true);
        let mut prev = 0.0;
        for e in 0..1000 {
            let a = c.mean_accuracy(e as f64);
            assert!(a >= prev - 1e-12);
            prev = a;
        }
        assert!(prev < c.a_max);
        assert!(c.mean_accuracy(10.0 * c.tau) > 0.999 * c.a_max);
    }

    #[test]
    fn non_iid_is_slower() {
        for ds in [Dataset::Cifar10, Dataset::Cifar100] {
            let iid = LearningCurve::for_setting(ds, true);
            let non = LearningCurve::for_setting(ds, false);
            let mut r1 = Rng::new(1);
            let mut r2 = Rng::new(1);
            let t = ds.threshold(false);
            let e_iid = iid.epochs_to_threshold(t, 5000, &mut r1).unwrap();
            let e_non = non.epochs_to_threshold(t, 5000, &mut r2).unwrap();
            assert!(e_non > e_iid, "{ds:?}: {e_non} <= {e_iid}");
        }
    }

    #[test]
    fn epoch_counts_are_paper_scale() {
        // Hundreds of epochs, not tens or tens of thousands.
        let mut rng = Rng::new(3);
        let c = LearningCurve::for_setting(Dataset::Cifar10, true);
        let e = c
            .epochs_to_threshold(Dataset::Cifar10.threshold(true), 10_000, &mut rng)
            .unwrap();
        assert!((100..2000).contains(&e), "epochs={e}");
    }

    #[test]
    fn unreachable_threshold_returns_none() {
        let c = LearningCurve::for_setting(Dataset::Cifar100, false);
        let mut rng = Rng::new(4);
        assert!(c.epochs_to_threshold(0.99, 2000, &mut rng).is_none());
    }
}
