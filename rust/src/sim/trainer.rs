//! The SL training-delay simulator: drives epochs across the device fleet
//! with per-epoch link sampling and per-method partition decisions
//! (Sec. III-A's training process, evaluated as in Sec. VII-B).

use super::breakdown::DelayBreakdown;
use super::convergence::{Dataset, LearningCurve};
use crate::models;
use crate::net::{EdgeNetwork, NetConfig};
use crate::partition::baselines::{evaluate_static, oss_partition};
use crate::partition::{
    DecisionProvenance, FleetSpec, FleetStats, JointOptions, Link, MultiServerPlanner,
    PathPlanner, PathSpec, PlanRequest, PlannerService, Problem, ServiceOptions, SpecDelta,
};
use crate::profiles::{CostGraph, DeviceProfile, TrainCfg};
use crate::util::rng::Rng;
use std::time::Instant;

/// Churn faults injected by [`Trainer::run_churn_epochs`] (all disabled by
/// default, in which case that scenario reduces to a service-routed
/// [`Trainer::run_epochs`]).
#[derive(Clone, Copy, Debug)]
pub struct ChurnCfg {
    /// Per-epoch probability that an active device leaves the fleet.
    pub leave_prob: f64,
    /// Per-epoch probability that a departed device re-joins (as a new
    /// incarnation: fresh [`DeviceId`], random tier).
    pub rejoin_prob: f64,
    /// Per-epoch probability that an active device's link report is
    /// withheld (the service serves its last-good decision, marked
    /// [`DecisionProvenance::Degraded`], once the report goes stale).
    pub stale_prob: f64,
    /// Staleness bound handed to the planning service
    /// (`ServiceOptions::staleness_bound`); `u64::MAX` disables the
    /// degraded-mode policy entirely.
    pub staleness_bound: u64,
}

impl Default for ChurnCfg {
    fn default() -> Self {
        ChurnCfg {
            leave_prob: 0.0,
            rejoin_prob: 0.0,
            stale_prob: 0.0,
            staleness_bound: u64::MAX,
        }
    }
}

/// Simulation configuration for one scenario run.
#[derive(Clone, Debug)]
pub struct SimConfig {
    pub model: String,
    pub net: NetConfig,
    pub train: TrainCfg,
    /// One of `proposed`, `proposed-joint`, `proposed-multihop`,
    /// `proposed-multiserver`, `general`, `oss`, `regression`,
    /// `device-only`, `central`.
    pub method: String,
    pub seed: u64,
    /// Shared server capacity in concurrent full-throughput
    /// device-equivalents — only the `proposed-joint` method reads it
    /// (∞, the default, degenerates to the dedicated `proposed` engine).
    pub server_capacity: f64,
    /// Relay-path length for the `proposed-multihop` method: the epoch's
    /// split is a K-segment cut over a path of this many hops
    /// (`partition::multihop`). 1, the default, degenerates to the
    /// single device→server split.
    pub path_hops: usize,
    /// Per-server capacity vector for the `proposed-multiserver` method
    /// (`partition::assign`). Empty, the default, falls back to one
    /// server of `server_capacity`.
    pub server_capacities: Vec<f64>,
    /// Fault injection for [`Trainer::run_churn_epochs`] (disabled by
    /// default; the classic scenarios ignore it).
    pub churn: ChurnCfg,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            model: "googlenet".into(),
            net: NetConfig::default(),
            train: TrainCfg::default(),
            method: "proposed".into(),
            seed: 7,
            server_capacity: f64::INFINITY,
            path_hops: 1,
            server_capacities: Vec::new(),
            churn: ChurnCfg::default(),
        }
    }
}

/// Stable identity of one device *incarnation*. Slot indices are reused
/// when a device re-joins after a departure; the `DeviceId` is not —
/// records keep meaning "this physical participant" across churn.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct DeviceId(pub u64);

/// Record of one simulated epoch.
#[derive(Clone, Debug)]
pub struct EpochRecord {
    pub epoch: usize,
    /// Device *slot* index (reused across churn; see [`DeviceId`]).
    pub device: usize,
    /// Stable identity of the device incarnation the record is about —
    /// survives slot reuse when the fleet churns mid-run.
    pub device_id: DeviceId,
    pub device_tier: &'static str,
    pub link: Link,
    /// Eq. (7) epoch delay in (simulated) seconds. For the
    /// `proposed-joint` method this is the device's *load-dependent* delay
    /// under the shared server (see `partition::joint`), not the
    /// dedicated-server value.
    pub delay: f64,
    /// Wall-clock time the partition decision took (real seconds). For the
    /// "proposed" method this is the `FleetPlanner` facade's actual cost:
    /// a refresh + solve when the tier's link changed, a cache fan-out when
    /// it did not — `decision_refreshed` says which one was measured.
    pub decision_time: f64,
    /// True iff the decision ran a fresh solve (always true for baseline
    /// methods, which have no cache; false only when the fleet facade
    /// served the tier's bit-identical cached decision).
    pub decision_refreshed: bool,
    /// Where the decision came from — fresh solve, warm cache, or the
    /// churn service's degraded fallback (baselines report `Fresh`).
    pub provenance: DecisionProvenance,
    pub device_layers: usize,
    /// The dedicated Eq. (7) decomposition of the chosen cut. For
    /// `proposed-joint` on a congested epoch its components sum to the
    /// cut's dedicated delay `A + W`, not to the recorded `delay` above —
    /// the gap `delay − (A + W)` is the shared-server queueing share,
    /// which has no per-term decomposition.
    pub breakdown: DelayBreakdown,
}

/// Aggregate result of a scenario run.
#[derive(Clone, Debug)]
pub struct SimResult {
    pub records: Vec<EpochRecord>,
    pub total_delay: f64,
    pub mean_epoch_delay: f64,
    /// Mean wall-clock of the partition decisions that ran a fresh solve
    /// (cache-hit epochs are excluded; see `summarize`).
    pub mean_decision_time: f64,
    /// Recorded epochs whose decision was served by the churn service's
    /// degraded fallback ([`DecisionProvenance::Degraded`]); always 0 for
    /// the classic (churn-free) scenarios.
    pub degraded_decisions: u64,
}

/// The simulator: a fleet of heterogeneous devices + one server + network.
pub struct Trainer {
    cfg: SimConfig,
    net: EdgeNetwork,
    fleet: Vec<DeviceProfile>,
    /// The planning stack behind "proposed" and "proposed-joint": the
    /// churn-tolerant service wrapping the joint facade over deduplicated
    /// per-tier cost graphs + transformed networks, built once. The
    /// classic scenarios call straight through to the planner
    /// (`service.planner_mut()` — a transparent pass-through that keeps
    /// the pinned planner-stats counters unchanged); only
    /// [`Trainer::run_churn_epochs`] engages the service's report inbox
    /// and degraded-mode epoch loop. For "proposed" the capacity is ∞, so
    /// the joint facade delegates to the plain fleet engine
    /// bit-identically; for "proposed-joint" the epoch decision covers
    /// the whole fleet at once — cuts coupled through
    /// `cfg.server_capacity` — and the recorded delay is the selected
    /// device's load-dependent delay.
    service: PlannerService,
    /// Per-tier K-segment path planners behind "proposed-multihop"
    /// (`partition::multihop`): each tier's cost graph lifted onto a
    /// `cfg.path_hops`-hop relay ladder. Empty for every other method.
    paths: Vec<PathPlanner>,
    /// The device→server assignment planner behind "proposed-multiserver"
    /// (`partition::assign`); `None` for every other method.
    multi: Option<MultiServerPlanner>,
    /// Stable per-slot incarnation ids (see [`DeviceId`]); re-joins mint
    /// fresh ids from `next_device_id`.
    device_ids: Vec<DeviceId>,
    next_device_id: u64,
    /// OSS static partition: ONE fixed cut for the whole system ([17]
    /// optimizes a single static split), chosen for the median device tier
    /// at nominal rates on the first epoch.
    oss_fixed: Option<Vec<bool>>,
    sim_time: f64,
}

impl Trainer {
    pub fn new(cfg: SimConfig) -> Trainer {
        let model = models::by_name(&cfg.model)
            .unwrap_or_else(|| panic!("unknown model '{}'", cfg.model));
        let server = DeviceProfile::rtx_a6000();
        let fleet = if cfg.net.num_devices == 20 {
            DeviceProfile::paper_fleet()
        } else {
            DeviceProfile::fleet_of(cfg.net.num_devices)
        };
        let spec =
            FleetSpec::from_fleet(&fleet, |d| CostGraph::build(&model, d, &server, &cfg.train));
        // One planning stack for every method: the joint facade at ∞
        // capacity is bit-identical to the plain fleet engine, so only
        // "proposed-joint" reads the configured finite capacity.
        let capacity = if cfg.method == "proposed-joint" {
            cfg.server_capacity
        } else {
            f64::INFINITY
        };
        let num_devices = spec.num_devices();
        // The PR-10 topology planners ride next to the service stack:
        // per-tier relay-path planners for "proposed-multihop" (the
        // sampled end-to-end link split across `path_hops` hops), and the
        // assignment planner for "proposed-multiserver" (per-server
        // capacity vector; empty falls back to one `server_capacity`
        // server, which delegates to the joint engine bit-identically).
        let paths: Vec<PathPlanner> = if cfg.method == "proposed-multihop" {
            (0..spec.num_tiers())
                .map(|t| {
                    PathPlanner::new(PathSpec::relayed(
                        spec.tier_costs(t),
                        cfg.path_hops.max(1) - 1,
                    ))
                })
                .collect()
        } else {
            Vec::new()
        };
        let multi = (cfg.method == "proposed-multiserver").then(|| {
            let capacities = if cfg.server_capacities.is_empty() {
                vec![cfg.server_capacity]
            } else {
                cfg.server_capacities.clone()
            };
            MultiServerPlanner::with_capacities(spec.clone(), capacities)
        });
        let service = PlannerService::new(
            spec,
            ServiceOptions {
                staleness_bound: cfg.churn.staleness_bound,
                solve_budget: u64::MAX,
                joint: JointOptions::with_capacity(capacity),
            },
        );
        let net = EdgeNetwork::new(cfg.net.clone());
        Trainer {
            cfg,
            net,
            fleet,
            service,
            paths,
            multi,
            device_ids: (0..num_devices as u64).map(DeviceId).collect(),
            next_device_id: num_devices as u64,
            oss_fixed: None,
            sim_time: 0.0,
        }
    }

    /// Current simulated time (seconds since scenario start).
    pub fn sim_time(&self) -> f64 {
        self.sim_time
    }

    /// Run one epoch: select device, sample link, decide partition, account
    /// delay (Sec. III-A).
    pub fn run_epoch(&mut self, epoch: usize) -> EpochRecord {
        let device = self.net.select_device(self.sim_time);
        let tier = self.service.spec().tier_of(device);
        let link = self.net.sample_link(device, self.sim_time).to_link();
        let tier_name = self.service.spec().tier_name(tier);

        // Multi-hop epochs: the sampled link is the end-to-end path
        // budget; each hop carries `hops`× its rates so the serial (σ-
        // additive) composition reproduces it, and hops = 1 hands the
        // sampled link to the planner verbatim (the degenerate pin).
        if self.cfg.method == "proposed-multihop" {
            let hops = self.cfg.path_hops.max(1);
            let hop_links: Vec<Link> = (0..hops)
                .map(|_| Link {
                    up_bps: link.up_bps * hops as f64,
                    down_bps: link.down_bps * hops as f64,
                })
                .collect();
            let t0 = Instant::now();
            let plan = self.paths[tier].plan(&hop_links);
            let decision_time = t0.elapsed().as_secs_f64();
            let partition = crate::partition::Partition {
                device_set: plan.cuts[0].clone(),
                delay: plan.delay,
            };
            let problem = Problem::new(self.service.spec().tier_costs(tier), link);
            // The dedicated single-split decomposition of the device-side
            // cut; on a genuine relay path its components sum to that
            // cut's two-host delay, not to the K-segment `delay` above
            // (same caveat as the joint method's congested epochs).
            let breakdown = DelayBreakdown::of(&problem, &partition.device_set);
            let record = EpochRecord {
                epoch,
                device,
                device_id: self.device_ids[device],
                device_tier: tier_name,
                link,
                delay: partition.delay,
                decision_time,
                decision_refreshed: true,
                provenance: DecisionProvenance::Fresh,
                device_layers: partition.device_layers(),
                breakdown,
            };
            self.sim_time += partition.delay + decision_time;
            return record;
        }

        // Multi-server epochs mirror the joint method's fleet-wide batch,
        // with the assignment planner choosing each device's server.
        if self.cfg.method == "proposed-multiserver" {
            let requests: Vec<PlanRequest> = (0..self.service.spec().num_devices())
                .map(|d| {
                    let l = if d == device {
                        link
                    } else {
                        self.net.sample_link(d, self.sim_time).to_link()
                    };
                    PlanRequest {
                        device: d,
                        tier: self.service.spec().tier_of(d),
                        link: l,
                    }
                })
                .collect();
            let t0 = Instant::now();
            let decision = self
                .multi
                .as_mut()
                .expect("built for proposed-multiserver in Trainer::new")
                .plan(&requests)
                .into_iter()
                .find(|d| d.device == device)
                .expect("one decision per device");
            let decision_time = t0.elapsed().as_secs_f64();
            let problem = Problem::new(self.service.spec().tier_costs(tier), link);
            let breakdown = DelayBreakdown::of(&problem, &decision.partition.device_set);
            let record = EpochRecord {
                epoch,
                device,
                device_id: self.device_ids[device],
                device_tier: tier_name,
                link,
                delay: decision.partition.delay,
                decision_time,
                decision_refreshed: decision.stats.refreshed,
                provenance: decision.provenance,
                device_layers: decision.partition.device_layers(),
                breakdown,
            };
            self.sim_time += decision.partition.delay + decision_time;
            return record;
        }

        // Joint epochs cover the whole fleet, so every device's current
        // link is sampled up front — channel simulation, not decision
        // work, so it stays outside the timed region below. At infinite
        // capacity the coupled batch would decide identically to the
        // single-request fast path (the ∞ delegation), so it is skipped —
        // mirrors the Coordinator's `is_finite` gate.
        let joint_requests: Option<Vec<PlanRequest>> =
            (self.cfg.method == "proposed-joint" && self.cfg.server_capacity.is_finite()).then(|| {
                (0..self.service.spec().num_devices())
                    .map(|d| {
                        let l = if d == device {
                            link
                        } else {
                            self.net.sample_link(d, self.sim_time).to_link()
                        };
                        PlanRequest {
                            device: d,
                            tier: self.service.spec().tier_of(d),
                            link: l,
                        }
                    })
                    .collect()
            });

        // "proposed" needs `&mut self.planner`, so the shared `Problem`
        // (which borrows the tier's cost graph out of the planner's spec)
        // can only be built in the non-mutating branch.
        let t0 = Instant::now();
        let (partition, decision_refreshed, provenance) = if let Some(requests) = &joint_requests {
            // Joint epoch: the fleet competes for the shared server; the
            // cuts are decided in one coupled plan and the record tracks
            // the selected device's load-dependent delay.
            let decision = self
                .service
                .planner_mut()
                .plan(requests)
                .into_iter()
                .find(|d| d.device == device)
                .expect("one decision per device");
            (
                decision.partition,
                decision.stats.refreshed,
                decision.provenance,
            )
        } else if self.cfg.method == "proposed" || self.cfg.method == "proposed-joint" {
            // Single-request fast path — also serves "proposed-joint" at
            // infinite capacity, where the planner delegates to the plain
            // fleet engine bit-identically.
            let decision = self
                .service
                .planner_mut()
                .plan(&[PlanRequest { device, tier, link }])
                .pop()
                .expect("one decision per request");
            (
                decision.partition,
                decision.stats.refreshed,
                decision.provenance,
            )
        } else {
            let problem = Problem::new(self.service.spec().tier_costs(tier), link);
            let partition = match self.cfg.method.as_str() {
                "oss" => {
                    if self.oss_fixed.is_none() {
                        // One static cut for the fleet: median tier, nominal
                        // link.
                        let nominal = self.net.nominal_link(256);
                        let spec = self.service.spec();
                        let median_tier = spec.tier_costs(spec.num_tiers() / 2);
                        let fixed = oss_partition(&Problem::new(median_tier, nominal));
                        self.oss_fixed = Some(fixed.device_set);
                    }
                    let fixed = crate::partition::Partition {
                        device_set: self.oss_fixed.clone().unwrap(),
                        delay: 0.0,
                    };
                    evaluate_static(&problem, &fixed)
                }
                method => crate::partition::baselines::partition_by_method(method, &problem, link),
            };
            (partition, true, DecisionProvenance::Fresh)
        };
        let decision_time = t0.elapsed().as_secs_f64();

        let problem = Problem::new(self.service.spec().tier_costs(tier), link);
        let breakdown = DelayBreakdown::of(&problem, &partition.device_set);
        let record = EpochRecord {
            epoch,
            device,
            device_id: self.device_ids[device],
            device_tier: tier_name,
            link,
            delay: partition.delay,
            decision_time,
            decision_refreshed,
            provenance,
            device_layers: partition.device_layers(),
            breakdown,
        };
        self.sim_time += partition.delay + decision_time;
        record
    }

    /// Run a fixed number of epochs (Fig. 11/12/16 style).
    pub fn run_epochs(&mut self, epochs: usize) -> SimResult {
        let records: Vec<EpochRecord> = (0..epochs).map(|e| self.run_epoch(e)).collect();
        summarize(records)
    }

    /// Run a churn-enabled scenario through the planning service's epoch
    /// loop: per epoch the membership churns ([`ChurnCfg::leave_prob`] /
    /// [`ChurnCfg::rejoin_prob`] — a re-join is a new incarnation with a
    /// fresh [`DeviceId`]), every active device's true link is sampled,
    /// and its *report* is withheld with [`ChurnCfg::stale_prob`] (the
    /// service degrades stale devices to their last-good decision per
    /// [`ChurnCfg::staleness_bound`]). Epoch 0 is fault-free so every
    /// device decides at least once. Each epoch records the scheduler's
    /// selected device when it received a decision, else the first decided
    /// device; epochs where every device is silent record nothing.
    ///
    /// Bit-replayable for a fixed seed: unlike [`Trainer::run_epoch`], the
    /// simulated clock advances by the Eq. (7) epoch delay only — folding
    /// the wall-clock decision time in (it is still *recorded*) would leak
    /// real time into the fading trajectories and break the churn
    /// harness's determinism contract (RESILIENCE.md).
    pub fn run_churn_epochs(&mut self, epochs: usize) -> SimResult {
        let churn = self.cfg.churn;
        let mut rng = Rng::new(self.cfg.seed ^ 0xC4021);
        let mut records = Vec::new();
        for epoch in 0..epochs {
            let n = self.service.spec().num_devices();
            if epoch > 0 {
                for d in 0..n {
                    if self.service.spec().tier_of_opt(d).is_some() {
                        if rng.chance(churn.leave_prob) && self.service.spec().active_devices() > 1
                        {
                            self.service.apply_delta(&SpecDelta::RemoveDevice { device: d });
                        }
                    } else if rng.chance(churn.rejoin_prob) {
                        let tier = rng.index(self.service.spec().num_tiers());
                        self.service
                            .apply_delta(&SpecDelta::AddDevice { device: d, tier });
                        self.device_ids[d] = DeviceId(self.next_device_id);
                        self.next_device_id += 1;
                    }
                }
            }
            // Channel simulation: every active device's true link is
            // sampled once; the report is withheld with `stale_prob`,
            // except on a device's first decided epoch (no cache to
            // degrade to yet — the service would bootstrap against the
            // stale link anyway, so report it fresh instead).
            let mut true_links: Vec<Option<Link>> = vec![None; n];
            for d in 0..n {
                if self.service.spec().tier_of_opt(d).is_none() {
                    continue;
                }
                let link = self.net.sample_link(d, self.sim_time).to_link();
                true_links[d] = Some(link);
                let first = self.service.last_good(d).is_none();
                if epoch == 0 || first || !rng.chance(churn.stale_prob) {
                    self.service.report(d, link, epoch as u64);
                }
            }
            let t0 = Instant::now();
            let decisions = self
                .service
                .plan_epoch(epoch as u64)
                .expect("the simulator's epoch clock is monotone");
            let decision_time = t0.elapsed().as_secs_f64();
            if decisions.is_empty() {
                continue;
            }
            let scheduled = self.net.select_device(self.sim_time);
            let decision = decisions
                .iter()
                .find(|x| x.device == scheduled)
                .unwrap_or(&decisions[0]);
            let device = decision.device;
            let tier = decision.tier;
            let link = true_links[device].expect("decided devices are active");
            let problem = Problem::new(self.service.spec().tier_costs(tier), link);
            let breakdown = DelayBreakdown::of(&problem, &decision.partition.device_set);
            records.push(EpochRecord {
                epoch,
                device,
                device_id: self.device_ids[device],
                device_tier: self.service.spec().tier_name(tier),
                link,
                delay: decision.partition.delay,
                decision_time,
                decision_refreshed: decision.stats.refreshed,
                provenance: decision.provenance,
                device_layers: decision.partition.device_layers(),
                breakdown,
            });
            self.sim_time += decision.partition.delay;
        }
        summarize(records)
    }

    /// Run until the learning curve hits the dataset threshold
    /// (Fig. 13-15 / Table II style). Returns the result and epoch count.
    pub fn run_to_accuracy(
        &mut self,
        dataset: Dataset,
        iid: bool,
        max_epochs: usize,
    ) -> (SimResult, usize) {
        let curve = LearningCurve::for_setting(dataset, iid);
        let mut rng = Rng::new(self.cfg.seed ^ 0xACC);
        let epochs = curve
            .epochs_to_threshold(dataset.threshold(iid), max_epochs, &mut rng)
            .unwrap_or(max_epochs);
        (self.run_epochs(epochs), epochs)
    }

    /// The device fleet (for reporting).
    pub fn fleet(&self) -> &[DeviceProfile] {
        &self.fleet
    }

    /// Solver counters of the fleet planning facade behind the "proposed"
    /// method — or, when the scenario runs "proposed-joint", of the joint
    /// facade (whose `price_iterations`/`joint_resolves` expose the
    /// shared-capacity price loop). The `reduced_*` vs `full_*` fields
    /// prove block-structured models decide epochs on the Theorem 2
    /// reduced DAG (the Table I decision-time metric measures
    /// blockwise-scale solves, not full-DAG ones — see the regression test
    /// below). The PR-10 topology methods route to their own planners:
    /// "proposed-multihop" folds the per-tier path planners' counters
    /// (additive fields summed, shape fields from tier 0),
    /// "proposed-multiserver" reports the assignment planner's folded
    /// per-server counters.
    pub fn planner_stats(&self) -> FleetStats {
        if !self.paths.is_empty() {
            let mut acc = self.paths[0].stats();
            for p in &self.paths[1..] {
                crate::partition::multihop::fold_counters(&mut acc, &p.stats());
            }
            return acc;
        }
        if let Some(m) = &self.multi {
            return m.stats();
        }
        self.service.stats()
    }

    /// The planning service behind the scenario (for churn-test
    /// introspection: last-good cache, degraded counters, live spec).
    pub fn service(&self) -> &PlannerService {
        &self.service
    }

    /// The planner's Prometheus scrape (the [`crate::daemon::metrics`]
    /// service families); `fastsplit simulate --metrics` dumps it after
    /// a run, and `benches/churn.rs` prints it per case.
    pub fn render_prometheus(&self) -> String {
        crate::daemon::metrics::render_prometheus(&crate::daemon::metrics::service_metrics(
            &self.service,
        ))
    }

    /// Current per-slot device incarnation ids (see [`DeviceId`]).
    pub fn device_ids(&self) -> &[DeviceId] {
        &self.device_ids
    }
}

fn summarize(records: Vec<EpochRecord>) -> SimResult {
    let total_delay: f64 = records.iter().map(|r| r.delay).sum();
    let mean_epoch_delay = total_delay / records.len().max(1) as f64;
    // Decision time is the paper's per-solve metric, so average only the
    // epochs that ran a fresh solve: baselines always do, but the fleet
    // facade may serve a bit-identical cached decision when a tier's link
    // repeats, and folding those ~cache-lookup times in would make the
    // cross-method comparison measure different things. Falls back to all
    // epochs if none solved (degenerate all-cached runs).
    let solved: Vec<f64> = records
        .iter()
        .filter(|r| r.decision_refreshed)
        .map(|r| r.decision_time)
        .collect();
    let mean_decision_time = if solved.is_empty() {
        records.iter().map(|r| r.decision_time).sum::<f64>() / records.len().max(1) as f64
    } else {
        solved.iter().sum::<f64>() / solved.len() as f64
    };
    let degraded_decisions = records
        .iter()
        .filter(|r| matches!(r.provenance, DecisionProvenance::Degraded(_)))
        .count() as u64;
    SimResult {
        records,
        total_delay,
        mean_epoch_delay,
        mean_decision_time,
        degraded_decisions,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::ChannelCondition;

    fn quick_cfg(method: &str) -> SimConfig {
        SimConfig {
            model: "block-residual".into(),
            net: NetConfig {
                num_devices: 4,
                ..NetConfig::default()
            },
            method: method.into(),
            seed: 11,
            ..SimConfig::default()
        }
    }

    #[test]
    fn epochs_accumulate_time() {
        let mut t = Trainer::new(quick_cfg("proposed"));
        let r = t.run_epochs(8);
        assert_eq!(r.records.len(), 8);
        assert!(r.total_delay > 0.0);
        assert!((t.sim_time() - (r.total_delay + r.records.iter().map(|x| x.decision_time).sum::<f64>())).abs() < 1e-9);
    }

    #[test]
    fn proposed_beats_baselines_on_average() {
        // Methods see different absolute times (their own delays advance the
        // clock), so the comparison is statistical: over enough epochs the
        // per-epoch-optimal method must win on mean delay. (Exact per-link
        // optimality vs every baseline is covered in
        // `partition::baselines::tests::brute_force_is_a_lower_bound`.)
        let run = |method: &str| {
            let mut t = Trainer::new(quick_cfg(method));
            t.run_epochs(60).mean_epoch_delay
        };
        let proposed = run("proposed");
        // `central` is excluded: it is the privacy-violating reference that
        // ships raw data for free and lower-bounds everything.
        for baseline in ["oss", "device-only", "regression"] {
            let b = run(baseline);
            assert!(
                proposed <= b * 1.05,
                "{baseline}: proposed {proposed} vs baseline {b}"
            );
        }
    }

    /// Guards the PR-2 regression from recurring: the fleet facade used to
    /// run the full general engine per tier, so "proposed" decision stats
    /// (the Table I metric) measured full-DAG solves on block-structured
    /// zoo models. They must report reduced-DAG solves again.
    #[test]
    fn proposed_reports_reduced_dag_solves_for_block_models() {
        for model in ["block-residual", "resnet18", "gpt2"] {
            let mut cfg = quick_cfg("proposed");
            cfg.model = model.into();
            let mut t = Trainer::new(cfg);
            t.run_epochs(3);
            let s = t.planner_stats();
            assert!(s.solves() > 0, "{model}: no decision solved");
            assert!(s.blocks_abstracted > 0, "{model}: no blocks abstracted");
            assert!(
                s.reduced_vertices < s.full_vertices && s.reduced_edges < s.full_edges,
                "{model}: decisions solved on {}v/{}e, full DAG {}v/{}e — \
                 not a reduced-DAG solve",
                s.reduced_vertices,
                s.reduced_edges,
                s.full_vertices,
                s.full_edges
            );
        }
    }

    /// The "proposed-joint" method row: a tight shared server must run the
    /// price loop (congestion counters move) and can only slow epochs down
    /// relative to what its own dedicated-server decisions would cost —
    /// while ∞ capacity never prices at all.
    #[test]
    fn proposed_joint_prices_the_shared_server() {
        let mut cfg = quick_cfg("proposed-joint");
        cfg.model = "googlenet".into();
        cfg.server_capacity = 0.4;
        let mut t = Trainer::new(cfg);
        let r = t.run_epochs(6);
        assert_eq!(r.records.len(), 6);
        let s = t.planner_stats();
        assert_eq!(s.plans, 6, "one joint plan per epoch");
        assert_eq!(s.requests, 6 * 4, "each plan covers the whole fleet");
        assert!(
            s.price_iterations > 0 && s.joint_resolves > 0,
            "capacity 0.4 over 4 devices must congest at least one epoch"
        );

        let mut cfg = quick_cfg("proposed-joint");
        cfg.server_capacity = f64::INFINITY;
        let mut t = Trainer::new(cfg);
        let _ = t.run_epochs(4);
        let s = t.planner_stats();
        assert_eq!(s.price_iterations, 0);
        assert_eq!(s.joint_resolves, 0);
    }

    /// The "proposed-multihop" method row: a 3-hop relay ladder plans a
    /// K-segment cut per epoch through the per-tier path planners (whose
    /// folded counters are the reported stats), and one hop degenerates
    /// to the single device→server split — epoch 0, before the simulated
    /// clocks can diverge, must agree with "proposed" on cost.
    #[test]
    fn proposed_multihop_runs_relay_ladders_and_degenerates_at_one_hop() {
        let mut cfg = quick_cfg("proposed-multihop");
        cfg.path_hops = 3;
        let mut t = Trainer::new(cfg);
        let r = t.run_epochs(6);
        assert_eq!(r.records.len(), 6);
        assert!(r
            .records
            .iter()
            .all(|x| x.delay.is_finite() && x.delay > 0.0));
        let s = t.planner_stats();
        assert!(s.plans > 0, "path planners never planned");
        assert!(
            s.flow_solves + s.linear_scans > 0,
            "path planners never solved a stage"
        );

        // One hop: the first epoch samples the same link as a fresh
        // "proposed" run (both clocks start at 0), so the single-split
        // delays must be cost-equal, and the degenerate path never
        // fires the nested-cut DP.
        let mut cfg = quick_cfg("proposed-multihop");
        cfg.path_hops = 1;
        let mut hop1 = Trainer::new(cfg);
        let a = hop1.run_epoch(0);
        assert_eq!(hop1.planner_stats().dp_transitions, 0);
        let mut base = Trainer::new(quick_cfg("proposed"));
        let b = base.run_epoch(0);
        assert_eq!(a.device, b.device, "epoch-0 scheduling must agree");
        assert_eq!(a.link.up_bps.to_bits(), b.link.up_bps.to_bits());
        crate::util::prop::assert_fleet_cost_equal(
            a.delay,
            b.delay,
            "1-hop multihop epoch 0 vs proposed epoch 0",
        );
    }

    /// The "proposed-multiserver" method row: a two-server capacity
    /// vector plans the whole fleet through the assignment planner each
    /// epoch; its folded per-server counters are the reported stats and
    /// every scored candidate assignment moves `inner_makespan_solves`.
    #[test]
    fn proposed_multiserver_assigns_devices_across_the_capacity_vector() {
        let mut cfg = quick_cfg("proposed-multiserver");
        cfg.server_capacities = vec![0.3, 0.4];
        let mut t = Trainer::new(cfg);
        let r = t.run_epochs(4);
        assert_eq!(r.records.len(), 4);
        assert!(r
            .records
            .iter()
            .all(|x| x.delay.is_finite() && x.delay > 0.0));
        let s = t.planner_stats();
        assert!(s.plans > 0, "assignment planner never planned");
        assert!(
            s.inner_makespan_solves > 0,
            "assignment search never scored a candidate"
        );
    }

    #[test]
    fn run_to_accuracy_scales_with_difficulty() {
        let mut easy = Trainer::new(quick_cfg("proposed"));
        let (_, e_iid) = easy.run_to_accuracy(Dataset::Cifar10, true, 5000);
        let mut hard = Trainer::new(quick_cfg("proposed"));
        let (_, e_non) = hard.run_to_accuracy(Dataset::Cifar10, false, 5000);
        assert!(e_non > e_iid);
    }

    #[test]
    fn decision_time_is_fast() {
        let mut t = Trainer::new(SimConfig {
            model: "googlenet".into(),
            ..quick_cfg("proposed")
        });
        let r = t.run_epochs(5);
        // Paper Table I: milliseconds. Allow debug-build slack.
        assert!(
            r.mean_decision_time < 0.5,
            "decision {}s",
            r.mean_decision_time
        );
    }

    /// Fault-free churn runs are just the service-routed epoch loop: every
    /// epoch records a decision, nothing degrades, and the run is
    /// reproducible bit-for-bit from the seed.
    #[test]
    fn churn_scenario_without_faults_never_degrades() {
        let mut cfg = quick_cfg("proposed");
        cfg.model = "googlenet".into();
        let mut t = Trainer::new(cfg);
        let r = t.run_churn_epochs(8);
        assert_eq!(r.records.len(), 8);
        assert_eq!(r.degraded_decisions, 0);
        assert_eq!(t.service().degraded_stale(), 0);
        assert_eq!(t.service().degraded_budget(), 0);
        assert!(r
            .records
            .iter()
            .all(|x| !matches!(x.provenance, DecisionProvenance::Degraded(_))));
    }

    #[test]
    fn churn_runs_are_deterministic_for_a_fixed_seed() {
        let run = || {
            let mut cfg = quick_cfg("proposed");
            cfg.churn = ChurnCfg {
                leave_prob: 0.2,
                rejoin_prob: 0.7,
                stale_prob: 0.3,
                staleness_bound: 0,
            };
            let mut t = Trainer::new(cfg);
            let r = t.run_churn_epochs(20);
            let delays: Vec<u64> = r.records.iter().map(|x| x.delay.to_bits()).collect();
            let ids: Vec<DeviceId> = t.device_ids().to_vec();
            (delays, ids, r.degraded_decisions)
        };
        assert_eq!(run(), run());
    }

    /// Withheld reports under a zero staleness bound must produce degraded
    /// decisions, and the per-run accounting has to line up: the service's
    /// counters partition its FleetStats total, and the records only ever
    /// see a subset of it (one record per epoch).
    #[test]
    fn churn_stale_reports_are_counted_consistently() {
        let mut cfg = quick_cfg("proposed");
        cfg.model = "googlenet".into();
        cfg.churn = ChurnCfg {
            leave_prob: 0.0,
            rejoin_prob: 0.0,
            stale_prob: 0.5,
            staleness_bound: 0,
        };
        let mut t = Trainer::new(cfg);
        let r = t.run_churn_epochs(20);
        assert_eq!(r.records.len(), 20, "no membership churn, so every epoch decides");
        let s = t.service().stats();
        assert!(t.service().degraded_stale() > 0, "stale_prob 0.5 over 20 epochs must degrade");
        assert_eq!(
            s.degraded_decisions,
            t.service().degraded_stale() + t.service().degraded_budget()
        );
        assert!(r.degraded_decisions <= s.degraded_decisions);
        // Every degraded record was served from the last-good cache, not a
        // fresh solve.
        assert!(r
            .records
            .iter()
            .filter(|x| matches!(x.provenance, DecisionProvenance::Degraded(_)))
            .all(|x| !x.decision_refreshed));
    }

    /// Slot reuse across churn must not alias identities: every re-join is
    /// a fresh incarnation, so the live id set stays duplicate-free and
    /// grows past the initial fleet once devices cycle.
    #[test]
    fn churn_rejoins_mint_fresh_device_ids() {
        let mut cfg = quick_cfg("proposed");
        cfg.churn = ChurnCfg {
            leave_prob: 0.5,
            rejoin_prob: 0.9,
            stale_prob: 0.0,
            staleness_bound: u64::MAX,
        };
        let n = cfg.net.num_devices;
        let mut t = Trainer::new(cfg);
        let _ = t.run_churn_epochs(30);
        assert!(t.service().spec().active_devices() >= 1, "fleet never empties");
        let ids = t.device_ids().to_vec();
        let mut sorted = ids.clone();
        sorted.sort();
        sorted.dedup();
        assert_eq!(sorted.len(), ids.len(), "device ids must stay unique");
        assert!(
            ids.iter().any(|id| id.0 >= n as u64),
            "heavy churn over 30 epochs must have minted at least one new incarnation"
        );
    }

    #[test]
    fn channel_condition_orders_delays() {
        let run = |cond: ChannelCondition| {
            let mut cfg = quick_cfg("proposed");
            cfg.net.condition = cond;
            cfg.net.rayleigh = false;
            let mut t = Trainer::new(cfg);
            t.run_epochs(30).mean_epoch_delay
        };
        let good = run(ChannelCondition::Good);
        let poor = run(ChannelCondition::Poor);
        // Poor shadowing (σ=6dB) increases mean delay (asymmetric CQI loss).
        assert!(poor > good * 0.9, "good={good} poor={poor}");
    }
}
