//! Split-learning training-delay simulator (Sec. VII-B).
//!
//! Drives the full SL loop of Sec. III-A in simulated time: per epoch the
//! server samples the selected device's link state, the chosen method
//! decides a partition, and the epoch delay follows Eq. (7). Convergence
//! experiments (Fig. 13-15, Table II) additionally model epochs-to-accuracy
//! with parameterized learning curves ([`convergence`], a documented
//! substitution for real CIFAR training — DESIGN.md §Substitutions).

pub mod trainer;
pub mod convergence;
pub mod breakdown;

pub use breakdown::DelayBreakdown;
pub use convergence::{Dataset, LearningCurve};
pub use trainer::{ChurnCfg, DeviceId, SimConfig, SimResult, Trainer};
