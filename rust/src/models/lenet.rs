//! LeNet-5 (LeCun et al., 1998) — the paper's canonical *linear* model.

use super::layer::{LayerKind, Shape};
use super::model::ModelGraph;

/// LeNet-5 over 1x32x32 input (classic digit classification sizing).
pub fn lenet5() -> ModelGraph {
    let (mut m, input) = ModelGraph::new("lenet5", Shape::chw(1, 32, 32));
    let c1 = m.add(
        LayerKind::Conv2d {
            out_ch: 6,
            kernel: 5,
            stride: 1,
            padding: 0,
        },
        &[input],
    );
    let r1 = m.add(LayerKind::Relu, &[c1]);
    let p1 = m.add(
        LayerKind::AvgPool {
            kernel: 2,
            stride: 2,
            padding: 0,
        },
        &[r1],
    );
    let c2 = m.add(
        LayerKind::Conv2d {
            out_ch: 16,
            kernel: 5,
            stride: 1,
            padding: 0,
        },
        &[p1],
    );
    let r2 = m.add(LayerKind::Relu, &[c2]);
    let p2 = m.add(
        LayerKind::AvgPool {
            kernel: 2,
            stride: 2,
            padding: 0,
        },
        &[r2],
    );
    let f = m.add(LayerKind::Flatten, &[p2]);
    let d1 = m.add(LayerKind::Dense { out_features: 120 }, &[f]);
    let r3 = m.add(LayerKind::Relu, &[d1]);
    let d2 = m.add(LayerKind::Dense { out_features: 84 }, &[r3]);
    let r4 = m.add(LayerKind::Relu, &[d2]);
    let d3 = m.add(LayerKind::Dense { out_features: 10 }, &[r4]);
    m.add(LayerKind::Softmax, &[d3]);
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn is_linear_and_sized_right() {
        let m = lenet5();
        assert!(m.is_linear());
        // Conv chain: 32 -> 28 -> 14 -> 10 -> 5, flatten 16*5*5 = 400.
        let flat = m
            .layers()
            .iter()
            .position(|l| matches!(l.kind, LayerKind::Flatten))
            .unwrap();
        assert_eq!(m.layer(flat).out_shape, Shape::features(400));
        // ~61,706 params in the classic LeNet-5 (with bias terms).
        let p = m.total_params();
        assert!((60_000..64_000).contains(&(p as usize)), "params={p}");
    }
}
