//! The three single-block networks of Fig. 6: a small stem, one non-linear
//! block (residual / inception / dense), and a classifier tail. These are
//! the workloads for Fig. 7 and Fig. 9(a), small enough that the
//! brute-force baseline remains tractable.

use super::layer::{LayerKind, Shape};
use super::model::ModelGraph;
use crate::graph::NodeId;

fn conv(out_ch: usize, kernel: usize, stride: usize, padding: usize) -> LayerKind {
    LayerKind::Conv2d {
        out_ch,
        kernel,
        stride,
        padding,
    }
}

fn tail(m: &mut ModelGraph, from: NodeId, classes: usize) -> NodeId {
    let gap = m.add(LayerKind::GlobalAvgPool, &[from]);
    let fc = m.add(LayerKind::Dense { out_features: classes }, &[gap]);
    m.add(LayerKind::Softmax, &[fc])
}

/// Fig. 6(a): network with one residual block (two 3x3 convs + skip add).
pub fn residual_blocknet() -> ModelGraph {
    let (mut m, input) = ModelGraph::new("block-residual", Shape::chw(3, 32, 32));
    let stem = m.add(conv(16, 3, 1, 1), &[input]);
    let stem_relu = m.add(LayerKind::Relu, &[stem]);

    // Residual block: branch from stem_relu.
    let c1 = m.add(conv(16, 3, 1, 1), &[stem_relu]);
    let r1 = m.add(LayerKind::Relu, &[c1]);
    let c2 = m.add(conv(16, 3, 1, 1), &[r1]);
    let add = m.add(LayerKind::Add, &[c2, stem_relu]);
    let out = m.add(LayerKind::Relu, &[add]);
    m.declare_block(vec![c1, r1, c2, add]);

    tail(&mut m, out, 10);
    m
}

/// Fig. 6(b): network with one inception block (1x1 / 3x3 / 5x5 / pool-proj
/// branches concatenated).
pub fn inception_blocknet() -> ModelGraph {
    let (mut m, input) = ModelGraph::new("block-inception", Shape::chw(3, 32, 32));
    let stem = m.add(conv(32, 3, 1, 1), &[input]);
    let stem_relu = m.add(LayerKind::Relu, &[stem]);

    // Branch 1: 1x1.
    let b1 = m.add(conv(16, 1, 1, 0), &[stem_relu]);
    // Branch 2: 1x1 -> 3x3.
    let b2a = m.add(conv(8, 1, 1, 0), &[stem_relu]);
    let b2b = m.add(conv(16, 3, 1, 1), &[b2a]);
    // Branch 3: 1x1 -> 5x5.
    let b3a = m.add(conv(4, 1, 1, 0), &[stem_relu]);
    let b3b = m.add(conv(8, 5, 1, 2), &[b3a]);
    // Branch 4: 3x3 maxpool -> 1x1.
    let b4a = m.add(
        LayerKind::MaxPool {
            kernel: 3,
            stride: 1,
            padding: 1,
        },
        &[stem_relu],
    );
    let b4b = m.add(conv(8, 1, 1, 0), &[b4a]);
    let cat = m.add(LayerKind::Concat, &[b1, b2b, b3b, b4b]);
    let out = m.add(LayerKind::Relu, &[cat]);
    m.declare_block(vec![b1, b2a, b2b, b3a, b3b, b4a, b4b, cat]);

    tail(&mut m, out, 10);
    m
}

/// Fig. 6(c): network with one dense block (each layer consumes the concat
/// of all previous outputs).
pub fn dense_blocknet() -> ModelGraph {
    let (mut m, input) = ModelGraph::new("block-dense", Shape::chw(3, 32, 32));
    let stem = m.add(conv(16, 3, 1, 1), &[input]);
    let stem_relu = m.add(LayerKind::Relu, &[stem]);

    // Dense connectivity over 3 conv layers with growth 8.
    let mut feeds = vec![stem_relu];
    let mut members = Vec::new();
    for _ in 0..3 {
        let cat_in = if feeds.len() == 1 {
            feeds[0]
        } else {
            let c = m.add(LayerKind::Concat, &feeds);
            members.push(c);
            c
        };
        let conv_l = m.add(conv(8, 3, 1, 1), &[cat_in]);
        let relu_l = m.add(LayerKind::Relu, &[conv_l]);
        members.push(conv_l);
        members.push(relu_l);
        feeds.push(relu_l);
    }
    let final_cat = m.add(LayerKind::Concat, &feeds);
    members.push(final_cat);
    m.declare_block(members);

    tail(&mut m, final_cat, 10);
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn residual_block_shapes() {
        let m = residual_blocknet();
        assert!(m.dag().is_acyclic());
        assert!(!m.is_linear());
        // Add output shape equals stem shape.
        let add = m
            .layers()
            .iter()
            .position(|l| matches!(l.kind, LayerKind::Add))
            .unwrap();
        assert_eq!(m.layer(add).out_shape, Shape::chw(16, 32, 32));
        assert_eq!(m.outputs().len(), 1);
    }

    #[test]
    fn inception_concat_channels() {
        let m = inception_blocknet();
        let cat = m
            .layers()
            .iter()
            .position(|l| matches!(l.kind, LayerKind::Concat))
            .unwrap();
        // 16 + 16 + 8 + 8 channels.
        assert_eq!(m.layer(cat).out_shape, Shape::chw(48, 32, 32));
    }

    #[test]
    fn dense_block_growth() {
        let m = dense_blocknet();
        let final_cat = m
            .layers()
            .iter()
            .rposition(|l| matches!(l.kind, LayerKind::Concat))
            .unwrap();
        // 16 stem + 3 * growth 8 = 40 channels.
        assert_eq!(m.layer(final_cat).out_shape, Shape::chw(40, 32, 32));
    }

    #[test]
    fn blocknets_are_brute_force_sized() {
        for name in super::super::BLOCK_NETS {
            let m = super::super::by_name(name).unwrap();
            assert!(
                m.len() <= 20,
                "{name} has {} layers; brute force needs small nets",
                m.len()
            );
            assert_eq!(m.declared_blocks().len(), 1);
        }
    }
}
