//! VGG-16 (Simonyan & Zisserman) — a deep linear model used to stress the
//! brute-force baseline's O(L) claim for linear networks.

use super::layer::{LayerKind, Shape};
use super::model::ModelGraph;
use crate::graph::NodeId;

fn conv_relu(m: &mut ModelGraph, from: NodeId, out_ch: usize) -> NodeId {
    let c = m.add(
        LayerKind::Conv2d {
            out_ch,
            kernel: 3,
            stride: 1,
            padding: 1,
        },
        &[from],
    );
    m.add(LayerKind::Relu, &[c])
}

/// VGG-16 (configuration D) over 3x224x224.
pub fn vgg16() -> ModelGraph {
    let (mut m, input) = ModelGraph::new("vgg16", Shape::chw(3, 224, 224));
    let mut x = input;
    for (reps, ch) in [(2usize, 64), (2, 128), (3, 256), (3, 512), (3, 512)] {
        for _ in 0..reps {
            x = conv_relu(&mut m, x, ch);
        }
        x = m.add(
            LayerKind::MaxPool {
                kernel: 2,
                stride: 2,
                padding: 0,
            },
            &[x],
        );
    }
    let f = m.add(LayerKind::Flatten, &[x]);
    let d1 = m.add(LayerKind::Dense { out_features: 4096 }, &[f]);
    let r1 = m.add(LayerKind::Relu, &[d1]);
    let d2 = m.add(LayerKind::Dense { out_features: 4096 }, &[r1]);
    let r2 = m.add(LayerKind::Relu, &[d2]);
    let d3 = m.add(LayerKind::Dense { out_features: 1000 }, &[r2]);
    m.add(LayerKind::Softmax, &[d3]);
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vgg16_analytics() {
        let m = vgg16();
        assert!(m.is_linear());
        // 138M params, ~15.5 GFLOPs forward per sample (MAC*2 = 31e9).
        let p = m.total_params() as f64 / 1e6;
        assert!((137.0..140.0).contains(&p), "params={p}M");
        let gf = m.total_flops() as f64 / 1e9;
        assert!((29.0..33.0).contains(&gf), "flops={gf}G");
    }
}
