//! GPT-2 small (124M) as a layer graph — the paper's Sec. VI-E extension
//! and Fig. 14 workload (trained on CARER). Each transformer block is a
//! repeated unit with an internal residual branch structure, so the
//! block-wise algorithm applies exactly as for CNNs.

use super::layer::{LayerKind, Shape};
use super::model::ModelGraph;
use crate::graph::NodeId;

/// One pre-norm transformer block:
/// `x + Attn(LN(x))` then `y + MLP(LN(y))`.
fn transformer_block(m: &mut ModelGraph, from: NodeId, heads: usize, dim: usize) -> NodeId {
    let first = m.len();
    let ln1 = m.add(LayerKind::LayerNorm, &[from]);
    let attn = m.add(LayerKind::SelfAttention { heads }, &[ln1]);
    let add1 = m.add(LayerKind::Add, &[from, attn]);
    let ln2 = m.add(LayerKind::LayerNorm, &[add1]);
    let fc1 = m.add(LayerKind::Dense { out_features: 4 * dim }, &[ln2]);
    let gelu = m.add(LayerKind::Gelu, &[fc1]);
    let fc2 = m.add(LayerKind::Dense { out_features: dim }, &[gelu]);
    let add2 = m.add(LayerKind::Add, &[add1, fc2]);
    m.declare_block((first..m.len()).collect());
    add2
}

/// GPT-2 with the given depth/width over a `seq_len` token sequence.
pub fn gpt2(layers: usize, heads: usize, dim: usize, seq_len: usize, vocab: usize) -> ModelGraph {
    let (mut m, input) = ModelGraph::new("gpt2", Shape::features(seq_len));
    let mut x = m.add(LayerKind::Embedding { vocab, dim }, &[input]);
    for _ in 0..layers {
        x = transformer_block(&mut m, x, heads, dim);
    }
    let lnf = m.add(LayerKind::LayerNorm, &[x]);
    let head = m.add(LayerKind::Dense { out_features: vocab }, &[lnf]);
    m.add(LayerKind::Softmax, &[head]);
    m
}

/// GPT-2 small: 12 layers, 12 heads, 768 dim, 50257 vocab, context 128
/// (CARER sequences are short utterances; 128 covers them).
pub fn gpt2_small() -> ModelGraph {
    gpt2(12, 12, 768, 128, 50257)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn twelve_blocks() {
        let m = gpt2_small();
        assert_eq!(m.declared_blocks().len(), 12);
        assert!(!m.is_linear());
    }

    #[test]
    fn parameter_count_matches_gpt2_small() {
        let m = gpt2_small();
        // 124M total incl. tied LM head counted separately here (head adds
        // ~38.6M): embedding 38.7M + 12 blocks x ~7.1M + head.
        let p = m.total_params() as f64 / 1e6;
        assert!((160.0..170.0).contains(&p), "params={p}M (untied head)");
        // Blocks alone: ~85M.
        let block_params: u64 = m
            .declared_blocks()
            .iter()
            .flatten()
            .map(|&v| m.layer(v).params)
            .sum();
        let bp = block_params as f64 / 1e6;
        assert!((83.0..88.0).contains(&bp), "block params={bp}M");
    }

    #[test]
    fn block_output_is_residual_stream() {
        let m = gpt2_small();
        for block in m.declared_blocks() {
            let last = *block.last().unwrap();
            assert_eq!(m.layer(last).out_shape, Shape::seq(128, 768));
        }
    }
}
