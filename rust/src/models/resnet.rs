//! ResNet-18 and ResNet-50 (He et al., 2016): the paper's block-structured
//! workhorses (8 basic blocks / 16 bottleneck blocks respectively).

use super::layer::{LayerKind, Shape};
use super::model::ModelGraph;
use crate::graph::NodeId;

fn conv(out_ch: usize, kernel: usize, stride: usize, padding: usize) -> LayerKind {
    LayerKind::Conv2d {
        out_ch,
        kernel,
        stride,
        padding,
    }
}

fn conv_bn(m: &mut ModelGraph, from: NodeId, k: LayerKind) -> NodeId {
    let c = m.add(k, &[from]);
    m.add(LayerKind::BatchNorm, &[c])
}

fn conv_bn_relu(m: &mut ModelGraph, from: NodeId, k: LayerKind) -> NodeId {
    let b = conv_bn(m, from, k);
    m.add(LayerKind::Relu, &[b])
}

/// Basic residual block (two 3x3 convs), optional downsampling projection.
fn basic_block(m: &mut ModelGraph, from: NodeId, out_ch: usize, stride: usize) -> NodeId {
    let first = m.len();
    let b1 = conv_bn_relu(m, from, conv(out_ch, 3, stride, 1));
    let b2 = conv_bn(m, b1, conv(out_ch, 3, 1, 1));
    let skip = if stride != 1 || needs_projection(m, from, out_ch) {
        conv_bn(m, from, conv(out_ch, 1, stride, 0))
    } else {
        from
    };
    let add = m.add(LayerKind::Add, &[b2, skip]);
    let out = m.add(LayerKind::Relu, &[add]);
    m.declare_block((first..m.len()).collect());
    out
}

/// Bottleneck block (1x1 -> 3x3 -> 1x1 with 4x expansion).
fn bottleneck_block(
    m: &mut ModelGraph,
    from: NodeId,
    mid_ch: usize,
    stride: usize,
) -> NodeId {
    let out_ch = mid_ch * 4;
    let first = m.len();
    let b1 = conv_bn_relu(m, from, conv(mid_ch, 1, 1, 0));
    let b2 = conv_bn_relu(m, b1, conv(mid_ch, 3, stride, 1));
    let b3 = conv_bn(m, b2, conv(out_ch, 1, 1, 0));
    let skip = if stride != 1 || needs_projection(m, from, out_ch) {
        conv_bn(m, from, conv(out_ch, 1, stride, 0))
    } else {
        from
    };
    let add = m.add(LayerKind::Add, &[b3, skip]);
    let out = m.add(LayerKind::Relu, &[add]);
    m.declare_block((first..m.len()).collect());
    out
}

fn needs_projection(m: &ModelGraph, from: NodeId, out_ch: usize) -> bool {
    m.layer(from).out_shape.dims()[0] != out_ch
}

fn stem(m: &mut ModelGraph, input: NodeId) -> NodeId {
    let c = conv_bn_relu(m, input, conv(64, 7, 2, 3));
    m.add(
        LayerKind::MaxPool {
            kernel: 3,
            stride: 2,
            padding: 1,
        },
        &[c],
    )
}

fn head(m: &mut ModelGraph, from: NodeId, classes: usize) -> NodeId {
    let gap = m.add(LayerKind::GlobalAvgPool, &[from]);
    let fc = m.add(LayerKind::Dense { out_features: classes }, &[gap]);
    m.add(LayerKind::Softmax, &[fc])
}

/// ResNet-18 over 3x224x224 (8 basic blocks, [2,2,2,2]).
pub fn resnet18() -> ModelGraph {
    let (mut m, input) = ModelGraph::new("resnet18", Shape::chw(3, 224, 224));
    let mut x = stem(&mut m, input);
    for (stage, &(ch, reps)) in [(64usize, 2usize), (128, 2), (256, 2), (512, 2)]
        .iter()
        .enumerate()
    {
        for r in 0..reps {
            let stride = if stage > 0 && r == 0 { 2 } else { 1 };
            x = basic_block(&mut m, x, ch, stride);
        }
    }
    head(&mut m, x, 1000);
    m
}

/// ResNet-50 over 3x224x224 (16 bottleneck blocks, [3,4,6,3]).
pub fn resnet50() -> ModelGraph {
    let (mut m, input) = ModelGraph::new("resnet50", Shape::chw(3, 224, 224));
    let mut x = stem(&mut m, input);
    for (stage, &(ch, reps)) in [(64usize, 3usize), (128, 4), (256, 6), (512, 3)]
        .iter()
        .enumerate()
    {
        for r in 0..reps {
            let stride = if stage > 0 && r == 0 { 2 } else { 1 };
            x = bottleneck_block(&mut m, x, ch, stride);
        }
    }
    head(&mut m, x, 1000);
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resnet18_matches_reference_analytics() {
        let m = resnet18();
        assert!(!m.is_linear());
        assert_eq!(m.declared_blocks().len(), 8, "8 basic blocks (paper Sec. VI-A)");
        // ~11.7M params, ~1.8 GFLOPs forward.
        let p = m.total_params() as f64 / 1e6;
        assert!((11.0..12.5).contains(&p), "params={p}M");
        let gf = m.total_flops() as f64 / 1e9;
        assert!((3.2..4.2).contains(&gf), "flops={gf}G (2*MACs)");
    }

    #[test]
    fn resnet50_matches_reference_analytics() {
        let m = resnet50();
        assert_eq!(m.declared_blocks().len(), 16, "16 bottleneck blocks");
        // ~25.6M params, ~4.1 GMACs -> 8.2 GFLOPs.
        let p = m.total_params() as f64 / 1e6;
        assert!((25.0..27.0).contains(&p), "params={p}M");
        let gf = m.total_flops() as f64 / 1e9;
        assert!((7.0..9.0).contains(&gf), "flops={gf}G");
    }

    #[test]
    fn spatial_resolution_halves_per_stage() {
        let m = resnet18();
        let out = m.outputs()[0];
        // Final softmax over 1000 classes.
        assert_eq!(m.layer(out).out_shape, Shape::features(1000));
        // GAP input is 512 x 7 x 7.
        let gap = m
            .layers()
            .iter()
            .position(|l| matches!(l.kind, LayerKind::GlobalAvgPool))
            .unwrap();
        let gap_in = m.dag().parents(gap)[0];
        assert_eq!(m.layer(gap_in).out_shape, Shape::chw(512, 7, 7));
    }
}
