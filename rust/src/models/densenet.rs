//! DenseNet-121 (Huang et al., 2017): 58 repeated dense layers — the
//! paper's deepest CNN (Sec. VI-A counts the dense layer as the repeating
//! block: 6 + 12 + 24 + 16 = 58 in DenseNet-121).

use super::layer::{LayerKind, Shape};
use super::model::ModelGraph;
use crate::graph::NodeId;

fn conv(out_ch: usize, kernel: usize, stride: usize, padding: usize) -> LayerKind {
    LayerKind::Conv2d {
        out_ch,
        kernel,
        stride,
        padding,
    }
}

/// One dense layer: BN-ReLU-Conv1x1(4k)-BN-ReLU-Conv3x3(k), output
/// concatenated with the input features.
fn dense_layer(m: &mut ModelGraph, from: NodeId, growth: usize) -> NodeId {
    let first = m.len();
    let bn1 = m.add(LayerKind::BatchNorm, &[from]);
    let r1 = m.add(LayerKind::Relu, &[bn1]);
    let c1 = m.add(conv(4 * growth, 1, 1, 0), &[r1]);
    let bn2 = m.add(LayerKind::BatchNorm, &[c1]);
    let r2 = m.add(LayerKind::Relu, &[bn2]);
    let c2 = m.add(conv(growth, 3, 1, 1), &[r2]);
    let cat = m.add(LayerKind::Concat, &[from, c2]);
    m.declare_block((first..m.len()).collect());
    cat
}

/// Transition: BN-ReLU-Conv1x1(channels/2)-AvgPool2.
fn transition(m: &mut ModelGraph, from: NodeId) -> NodeId {
    let ch = m.layer(from).out_shape.dims()[0] / 2;
    let bn = m.add(LayerKind::BatchNorm, &[from]);
    let r = m.add(LayerKind::Relu, &[bn]);
    let c = m.add(conv(ch, 1, 1, 0), &[r]);
    m.add(
        LayerKind::AvgPool {
            kernel: 2,
            stride: 2,
            padding: 0,
        },
        &[c],
    )
}

/// DenseNet-121 over 3x224x224 (growth rate 32, blocks [6,12,24,16]).
pub fn densenet121() -> ModelGraph {
    let (mut m, input) = ModelGraph::new("densenet121", Shape::chw(3, 224, 224));
    let growth = 32;
    let c1 = m.add(conv(64, 7, 2, 3), &[input]);
    let bn1 = m.add(LayerKind::BatchNorm, &[c1]);
    let r1 = m.add(LayerKind::Relu, &[bn1]);
    let mut x = m.add(
        LayerKind::MaxPool {
            kernel: 3,
            stride: 2,
            padding: 1,
        },
        &[r1],
    );
    for (i, reps) in [6usize, 12, 24, 16].into_iter().enumerate() {
        for _ in 0..reps {
            x = dense_layer(&mut m, x, growth);
        }
        if i < 3 {
            x = transition(&mut m, x);
        }
    }
    let bn = m.add(LayerKind::BatchNorm, &[x]);
    let r = m.add(LayerKind::Relu, &[bn]);
    let gap = m.add(LayerKind::GlobalAvgPool, &[r]);
    let fc = m.add(LayerKind::Dense { out_features: 1000 }, &[gap]);
    m.add(LayerKind::Softmax, &[fc]);
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifty_eight_dense_blocks() {
        let m = densenet121();
        assert_eq!(m.declared_blocks().len(), 58, "6+12+24+16 (paper Sec. VI-A)");
    }

    #[test]
    fn reference_analytics() {
        let m = densenet121();
        // ~8.0M params, ~2.9 GMACs -> 5.7 GFLOPs.
        let p = m.total_params() as f64 / 1e6;
        assert!((7.5..8.6).contains(&p), "params={p}M");
        let gf = m.total_flops() as f64 / 1e9;
        assert!((5.0..6.5).contains(&gf), "flops={gf}G");
    }

    #[test]
    fn channel_bookkeeping() {
        let m = densenet121();
        // Final dense block output: 512 + 16*32 = 1024 channels at 7x7.
        let gap = m
            .layers()
            .iter()
            .position(|l| matches!(l.kind, LayerKind::GlobalAvgPool))
            .unwrap();
        assert_eq!(m.layer(gap).out_shape, Shape::features(1024));
    }
}
