//! AlexNet (Krizhevsky et al., 2012) — the paper's second linear example.

use super::layer::{LayerKind, Shape};
use super::model::ModelGraph;
use crate::graph::NodeId;

fn conv(
    m: &mut ModelGraph,
    from: NodeId,
    out_ch: usize,
    kernel: usize,
    stride: usize,
    padding: usize,
) -> NodeId {
    let c = m.add(
        LayerKind::Conv2d {
            out_ch,
            kernel,
            stride,
            padding,
        },
        &[from],
    );
    m.add(LayerKind::Relu, &[c])
}

/// AlexNet over 3x224x224 (ImageNet sizing, as in the original).
pub fn alexnet() -> ModelGraph {
    let (mut m, input) = ModelGraph::new("alexnet", Shape::chw(3, 224, 224));
    let pool = |m: &mut ModelGraph, from| {
        m.add(
            LayerKind::MaxPool {
                kernel: 3,
                stride: 2,
                padding: 0,
            },
            &[from],
        )
    };
    let c1 = conv(&mut m, input, 64, 11, 4, 2);
    let p1 = pool(&mut m, c1);
    let c2 = conv(&mut m, p1, 192, 5, 1, 2);
    let p2 = pool(&mut m, c2);
    let c3 = conv(&mut m, p2, 384, 3, 1, 1);
    let c4 = conv(&mut m, c3, 256, 3, 1, 1);
    let c5 = conv(&mut m, c4, 256, 3, 1, 1);
    let p5 = pool(&mut m, c5);
    let f = m.add(LayerKind::Flatten, &[p5]);
    let d1 = m.add(LayerKind::Dense { out_features: 4096 }, &[f]);
    let r1 = m.add(LayerKind::Relu, &[d1]);
    let dr1 = m.add(LayerKind::Dropout, &[r1]);
    let d2 = m.add(LayerKind::Dense { out_features: 4096 }, &[dr1]);
    let r2 = m.add(LayerKind::Relu, &[d2]);
    let dr2 = m.add(LayerKind::Dropout, &[r2]);
    let d3 = m.add(LayerKind::Dense { out_features: 1000 }, &[dr2]);
    m.add(LayerKind::Softmax, &[d3]);
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_with_torchvision_sizing() {
        let m = alexnet();
        assert!(m.is_linear());
        // Feature extractor output: 256 x 6 x 6 -> flatten 9216.
        let flat = m
            .layers()
            .iter()
            .position(|l| matches!(l.kind, LayerKind::Flatten))
            .unwrap();
        assert_eq!(m.layer(flat).out_shape, Shape::features(9216));
        // ~61M parameters.
        let p = m.total_params() as f64 / 1e6;
        assert!((60.0..63.0).contains(&p), "params={p}M");
    }
}
