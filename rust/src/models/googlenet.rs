//! GoogLeNet (Szegedy et al., 2015): 9 inception blocks — the paper's
//! primary workload for the dynamic-network experiments (Fig. 11-13, 15, 16).

use super::layer::{LayerKind, Shape};
use super::model::ModelGraph;
use crate::graph::NodeId;

fn conv(out_ch: usize, kernel: usize, stride: usize, padding: usize) -> LayerKind {
    LayerKind::Conv2d {
        out_ch,
        kernel,
        stride,
        padding,
    }
}

fn conv_relu(m: &mut ModelGraph, from: NodeId, k: LayerKind) -> NodeId {
    let c = m.add(k, &[from]);
    m.add(LayerKind::Relu, &[c])
}

/// Inception block: (#1x1, #3x3 reduce, #3x3, #5x5 reduce, #5x5, pool proj).
#[allow(clippy::too_many_arguments)]
fn inception(
    m: &mut ModelGraph,
    from: NodeId,
    n1: usize,
    n3r: usize,
    n3: usize,
    n5r: usize,
    n5: usize,
    np: usize,
) -> NodeId {
    let first = m.len();
    let b1 = conv_relu(m, from, conv(n1, 1, 1, 0));
    let b2a = conv_relu(m, from, conv(n3r, 1, 1, 0));
    let b2b = conv_relu(m, b2a, conv(n3, 3, 1, 1));
    let b3a = conv_relu(m, from, conv(n5r, 1, 1, 0));
    let b3b = conv_relu(m, b3a, conv(n5, 5, 1, 2));
    let b4a = m.add(
        LayerKind::MaxPool {
            kernel: 3,
            stride: 1,
            padding: 1,
        },
        &[from],
    );
    let b4b = conv_relu(m, b4a, conv(np, 1, 1, 0));
    let cat = m.add(LayerKind::Concat, &[b1, b2b, b3b, b4b]);
    m.declare_block((first..m.len()).collect());
    cat
}

/// GoogLeNet over 3x224x224 (no auxiliary classifiers, as in inference-time
/// torchvision; 9 inception blocks).
pub fn googlenet() -> ModelGraph {
    let (mut m, input) = ModelGraph::new("googlenet", Shape::chw(3, 224, 224));
    let maxpool = |m: &mut ModelGraph, from| {
        m.add(
            LayerKind::MaxPool {
                kernel: 3,
                stride: 2,
                padding: 1,
            },
            &[from],
        )
    };
    let c1 = conv_relu(&mut m, input, conv(64, 7, 2, 3));
    let p1 = maxpool(&mut m, c1);
    let c2 = conv_relu(&mut m, p1, conv(64, 1, 1, 0));
    let c3 = conv_relu(&mut m, c2, conv(192, 3, 1, 1));
    let p2 = maxpool(&mut m, c3);

    let i3a = inception(&mut m, p2, 64, 96, 128, 16, 32, 32);
    let i3b = inception(&mut m, i3a, 128, 128, 192, 32, 96, 64);
    let p3 = maxpool(&mut m, i3b);
    let i4a = inception(&mut m, p3, 192, 96, 208, 16, 48, 64);
    let i4b = inception(&mut m, i4a, 160, 112, 224, 24, 64, 64);
    let i4c = inception(&mut m, i4b, 128, 128, 256, 24, 64, 64);
    let i4d = inception(&mut m, i4c, 112, 144, 288, 32, 64, 64);
    let i4e = inception(&mut m, i4d, 256, 160, 320, 32, 128, 128);
    let p4 = maxpool(&mut m, i4e);
    let i5a = inception(&mut m, p4, 256, 160, 320, 32, 128, 128);
    let i5b = inception(&mut m, i5a, 384, 192, 384, 48, 128, 128);

    let gap = m.add(LayerKind::GlobalAvgPool, &[i5b]);
    let drop = m.add(LayerKind::Dropout, &[gap]);
    let fc = m.add(LayerKind::Dense { out_features: 1000 }, &[drop]);
    m.add(LayerKind::Softmax, &[fc]);
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nine_inception_blocks() {
        let m = googlenet();
        assert_eq!(m.declared_blocks().len(), 9, "paper Sec. VI-A");
        assert!(!m.is_linear());
    }

    #[test]
    fn reference_analytics() {
        let m = googlenet();
        // ~6.6M params (no aux heads), ~1.5 GMACs -> 3 GFLOPs.
        let p = m.total_params() as f64 / 1e6;
        assert!((5.5..7.5).contains(&p), "params={p}M");
        let gf = m.total_flops() as f64 / 1e9;
        assert!((2.5..3.5).contains(&gf), "flops={gf}G");
    }

    #[test]
    fn inception_output_channels() {
        let m = googlenet();
        // Last concat: 384+384+128+128 = 1024 channels at 7x7.
        let cats: Vec<usize> = m
            .layers()
            .iter()
            .enumerate()
            .filter(|(_, l)| matches!(l.kind, LayerKind::Concat))
            .map(|(i, _)| i)
            .collect();
        let last = *cats.last().unwrap();
        assert_eq!(m.layer(last).out_shape, Shape::chw(1024, 7, 7));
    }
}
