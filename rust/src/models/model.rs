//! Model graph: a DAG of layers with computed analytics.

use super::layer::{LayerKind, Shape};
use crate::graph::{Dag, NodeId};

/// Bytes per activation / parameter element (fp32, matching the paper's
/// PyTorch profiling).
pub const BYTES_PER_ELEM: usize = 4;

/// One layer instance with its inferred analytics.
#[derive(Clone, Debug)]
pub struct LayerInfo {
    pub kind: LayerKind,
    pub name: String,
    pub out_shape: Shape,
    /// Forward FLOPs per sample.
    pub flops: u64,
    /// Trainable parameter count.
    pub params: u64,
}

impl LayerInfo {
    /// Parameter bytes `k_v` (Eq. (3)/(6)).
    pub fn param_bytes(&self) -> u64 {
        self.params * BYTES_PER_ELEM as u64
    }

    /// Smashed-data bytes per sample `a_v` (Eq. (4)/(5)).
    pub fn act_bytes(&self) -> u64 {
        (self.out_shape.numel() * BYTES_PER_ELEM) as u64
    }
}

/// A complete AI model: layer DAG + analytics + optional block ground truth.
#[derive(Clone, Debug)]
pub struct ModelGraph {
    name: String,
    dag: Dag,
    layers: Vec<LayerInfo>,
    /// Ground-truth repeated blocks (layer id sets), as declared by the
    /// architecture builders. Alg. 3 detects blocks structurally; this is
    /// kept for validation tests.
    declared_blocks: Vec<Vec<NodeId>>,
}

impl ModelGraph {
    /// Start building a model with a single input layer of the given shape.
    pub fn new<S: Into<String>>(name: S, input_shape: Shape) -> (ModelGraph, NodeId) {
        let mut dag = Dag::new();
        let input = dag.add_node("input");
        let m = ModelGraph {
            name: name.into(),
            dag,
            layers: vec![LayerInfo {
                kind: LayerKind::Input,
                name: "input".into(),
                out_shape: input_shape,
                flops: 0,
                params: 0,
            }],
            declared_blocks: Vec::new(),
        };
        (m, input)
    }

    /// Append a layer consuming `inputs`; returns its node id.
    pub fn add(&mut self, kind: LayerKind, inputs: &[NodeId]) -> NodeId {
        assert!(!inputs.is_empty(), "non-input layers need inputs");
        let in_shapes: Vec<&Shape> = inputs
            .iter()
            .map(|&i| &self.layers[i].out_shape)
            .collect();
        let out_shape = kind.infer_shape(&in_shapes);
        let flops = kind.flops(&in_shapes, &out_shape);
        let params = kind.params(&in_shapes, &out_shape);
        let idx = self.layers.len();
        let name = format!("{}_{}", kind.tag(), idx);
        let id = self.dag.add_node(name.clone());
        debug_assert_eq!(id, idx);
        for &i in inputs {
            self.dag.add_edge(i, id, 0.0);
        }
        self.layers.push(LayerInfo {
            kind,
            name,
            out_shape,
            flops,
            params,
        });
        id
    }

    /// Declare a ground-truth repeated block (for validation of Alg. 3).
    pub fn declare_block(&mut self, members: Vec<NodeId>) {
        self.declared_blocks.push(members);
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn dag(&self) -> &Dag {
        &self.dag
    }

    pub fn layer(&self, v: NodeId) -> &LayerInfo {
        &self.layers[v]
    }

    pub fn layers(&self) -> &[LayerInfo] {
        &self.layers
    }

    pub fn len(&self) -> usize {
        self.layers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.layers.is_empty()
    }

    pub fn declared_blocks(&self) -> &[Vec<NodeId>] {
        &self.declared_blocks
    }

    /// Total forward FLOPs per sample.
    pub fn total_flops(&self) -> u64 {
        self.layers.iter().map(|l| l.flops).sum()
    }

    /// Total trainable parameters.
    pub fn total_params(&self) -> u64 {
        self.layers.iter().map(|l| l.params).sum()
    }

    /// Mean activation (smashed-data) bytes across layers.
    pub fn mean_act_bytes(&self) -> f64 {
        self.layers.iter().map(|l| l.act_bytes() as f64).sum::<f64>() / self.len() as f64
    }

    /// True if no layer has more than one child (paper's "linear" class).
    pub fn is_linear(&self) -> bool {
        (0..self.len()).all(|v| self.dag.out_degree(v) <= 1)
    }

    /// Output (sink) layers — layers with no children.
    pub fn outputs(&self) -> Vec<NodeId> {
        (0..self.len())
            .filter(|&v| self.dag.out_degree(v) == 0)
            .collect()
    }

    /// One-line per-layer inventory (used by `fastsplit info`).
    pub fn describe(&self) -> String {
        let mut t = crate::util::table::Table::new(&[
            "id", "layer", "out-shape", "MFLOPs", "params", "act-bytes",
        ]);
        for (i, l) in self.layers.iter().enumerate() {
            t.row(&[
                i.to_string(),
                l.name.clone(),
                format!("{:?}", l.out_shape.dims()),
                format!("{:.2}", l.flops as f64 / 1e6),
                l.params.to_string(),
                l.act_bytes().to_string(),
            ]);
        }
        t.render()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> ModelGraph {
        let (mut m, input) = ModelGraph::new("tiny", Shape::chw(3, 8, 8));
        let c = m.add(
            LayerKind::Conv2d {
                out_ch: 4,
                kernel: 3,
                stride: 1,
                padding: 1,
            },
            &[input],
        );
        let r = m.add(LayerKind::Relu, &[c]);
        let f = m.add(LayerKind::Flatten, &[r]);
        m.add(LayerKind::Dense { out_features: 10 }, &[f]);
        m
    }

    #[test]
    fn builds_consistent_graph() {
        let m = tiny();
        assert_eq!(m.len(), 5);
        assert_eq!(m.dag().num_edges(), 4);
        assert!(m.is_linear());
        assert_eq!(m.outputs(), vec![4]);
        assert_eq!(m.layer(3).out_shape, Shape::features(4 * 8 * 8));
    }

    #[test]
    fn analytics_accumulate() {
        let m = tiny();
        let conv_flops = 2u64 * 4 * 8 * 8 * (3 * 3 * 3);
        let dense_flops = 2u64 * 256 * 10;
        assert_eq!(m.total_flops(), conv_flops + 256 + dense_flops);
        assert_eq!(m.total_params(), (4 * (27 + 1) + 10 * 257) as u64);
    }

    #[test]
    fn branching_is_nonlinear() {
        let (mut m, input) = ModelGraph::new("branchy", Shape::chw(3, 8, 8));
        let a = m.add(LayerKind::Relu, &[input]);
        let b = m.add(LayerKind::Relu, &[input]);
        m.add(LayerKind::Add, &[a, b]);
        assert!(!m.is_linear());
    }

    #[test]
    fn act_bytes_are_fp32() {
        let m = tiny();
        assert_eq!(m.layer(0).act_bytes(), (3 * 8 * 8 * 4) as u64);
    }
}
