//! AI-model layer graphs with exact shape, FLOPs, parameter, and
//! activation-size inference.
//!
//! The paper profiles per-layer compute time, parameter size `k_v` and
//! smashed-data size `a_v` with PyTorch hooks + torchstat on a Jetson
//! testbed (Sec. VII-B.1); here the same quantities are derived analytically
//! from the layer graph (see DESIGN.md §Substitutions). Architectures
//! reproduce the paper's evaluation set: the three single-block networks of
//! Fig. 6, LeNet/AlexNet/VGG16 (linear), ResNet18/50, GoogLeNet,
//! DenseNet121 (block-structured), and GPT-2 (Sec. VI-E / Fig. 14).

pub mod layer;
pub mod model;
pub mod blocknets;
pub mod lenet;
pub mod alexnet;
pub mod vgg;
pub mod resnet;
pub mod googlenet;
pub mod densenet;
pub mod gpt2;

pub use layer::{LayerKind, Shape};
pub use model::ModelGraph;

/// All zoo model names accepted by [`by_name`].
pub const MODEL_NAMES: &[&str] = &[
    "lenet5",
    "alexnet",
    "vgg16",
    "resnet18",
    "resnet50",
    "googlenet",
    "densenet121",
    "gpt2",
    "block-residual",
    "block-inception",
    "block-dense",
];

/// Build a zoo model by name (CIFAR-sized inputs for the CNNs).
pub fn by_name(name: &str) -> Option<ModelGraph> {
    match name {
        "lenet5" => Some(lenet::lenet5()),
        "alexnet" => Some(alexnet::alexnet()),
        "vgg16" => Some(vgg::vgg16()),
        "resnet18" => Some(resnet::resnet18()),
        "resnet50" => Some(resnet::resnet50()),
        "googlenet" => Some(googlenet::googlenet()),
        "densenet121" => Some(densenet::densenet121()),
        "gpt2" => Some(gpt2::gpt2_small()),
        "block-residual" => Some(blocknets::residual_blocknet()),
        "block-inception" => Some(blocknets::inception_blocknet()),
        "block-dense" => Some(blocknets::dense_blocknet()),
        _ => None,
    }
}

/// The four full AI models used in Fig. 8/9(b) and Tables I-II.
pub const FULL_MODELS: &[&str] = &["googlenet", "resnet18", "resnet50", "densenet121"];

/// The three single-block networks of Fig. 6/7/9(a).
pub const BLOCK_NETS: &[&str] = &["block-residual", "block-inception", "block-dense"];

/// Zoo models whose Theorem 2 block reduction abstracts at least one block
/// on the default device/server profiles (pinned by the
/// `partition::blockwise` and `experiments::fig14` suites) — the fleet-level
/// reduction must provably solve these on strictly smaller DAGs.
pub const REDUCING_MODELS: &[&str] =
    &["resnet18", "densenet121", "googlenet", "gpt2", "block-residual"];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_names_build() {
        for name in MODEL_NAMES {
            let m = by_name(name).unwrap_or_else(|| panic!("{name} missing"));
            assert!(m.len() > 2, "{name} too small");
            assert!(m.dag().is_acyclic(), "{name} has a cycle");
        }
        assert!(by_name("nonexistent").is_none());
    }
}
