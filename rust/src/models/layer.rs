//! Layer IR: kinds, tensor shapes, and per-layer analytics (shape
//! inference, forward FLOPs, parameter count).

/// Per-sample tensor shape (batch dimension excluded).
///
/// CNN activations are `[C, H, W]`; transformer activations are `[T, D]`;
/// flattened feature vectors are `[F]`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Shape(pub Vec<usize>);

impl Shape {
    pub fn chw(c: usize, h: usize, w: usize) -> Shape {
        Shape(vec![c, h, w])
    }

    pub fn features(f: usize) -> Shape {
        Shape(vec![f])
    }

    pub fn seq(t: usize, d: usize) -> Shape {
        Shape(vec![t, d])
    }

    /// Total elements per sample.
    pub fn numel(&self) -> usize {
        self.0.iter().product()
    }

    pub fn dims(&self) -> &[usize] {
        &self.0
    }
}

/// Layer kinds covering the paper's evaluation architectures.
#[derive(Clone, Debug, PartialEq)]
pub enum LayerKind {
    /// Data source; its "smashed data" is the raw input tensor.
    Input,
    /// 2D convolution (square kernel).
    Conv2d {
        out_ch: usize,
        kernel: usize,
        stride: usize,
        padding: usize,
    },
    /// Max pooling.
    MaxPool {
        kernel: usize,
        stride: usize,
        padding: usize,
    },
    /// Average pooling.
    AvgPool {
        kernel: usize,
        stride: usize,
        padding: usize,
    },
    /// Global average pooling to `[C]`.
    GlobalAvgPool,
    /// Fully connected layer applied to the last dimension.
    Dense { out_features: usize },
    /// Batch normalization over channels.
    BatchNorm,
    /// ReLU activation.
    Relu,
    /// GELU activation (transformer MLPs).
    Gelu,
    /// Elementwise sum of all inputs (residual connections).
    Add,
    /// Channel-dimension concatenation (inception / dense blocks).
    Concat,
    /// Flatten `[C,H,W]` -> `[C*H*W]`.
    Flatten,
    /// Dropout (no-op for sizing; kept for graph fidelity).
    Dropout,
    /// Token + positional embedding.
    Embedding { vocab: usize, dim: usize },
    /// Layer normalization over the last dimension.
    LayerNorm,
    /// Multi-head self-attention over `[T, D]`.
    SelfAttention { heads: usize },
    /// Softmax classifier head marker (elementwise-cost softmax).
    Softmax,
}

impl LayerKind {
    /// Short kind tag used in labels and DOT dumps.
    pub fn tag(&self) -> &'static str {
        match self {
            LayerKind::Input => "input",
            LayerKind::Conv2d { .. } => "conv",
            LayerKind::MaxPool { .. } => "maxpool",
            LayerKind::AvgPool { .. } => "avgpool",
            LayerKind::GlobalAvgPool => "gap",
            LayerKind::Dense { .. } => "dense",
            LayerKind::BatchNorm => "bn",
            LayerKind::Relu => "relu",
            LayerKind::Gelu => "gelu",
            LayerKind::Add => "add",
            LayerKind::Concat => "concat",
            LayerKind::Flatten => "flatten",
            LayerKind::Dropout => "dropout",
            LayerKind::Embedding { .. } => "embed",
            LayerKind::LayerNorm => "ln",
            LayerKind::SelfAttention { .. } => "attn",
            LayerKind::Softmax => "softmax",
        }
    }

    /// Infer the output shape from the input shapes.
    ///
    /// Panics with a descriptive message on arity/shape violations — model
    /// construction is build-time, so violations are programming errors.
    pub fn infer_shape(&self, inputs: &[&Shape]) -> Shape {
        let one = |what: &str| -> &Shape {
            assert!(
                inputs.len() == 1,
                "{what} expects exactly 1 input, got {}",
                inputs.len()
            );
            inputs[0]
        };
        match self {
            LayerKind::Input => {
                assert!(inputs.is_empty(), "input layer takes no inputs");
                unreachable!("input shape is supplied at construction")
            }
            LayerKind::Conv2d {
                out_ch,
                kernel,
                stride,
                padding,
            } => {
                let s = one("conv");
                let [_, h, w] = chw(s);
                Shape::chw(
                    *out_ch,
                    conv_dim(h, *kernel, *stride, *padding),
                    conv_dim(w, *kernel, *stride, *padding),
                )
            }
            LayerKind::MaxPool {
                kernel,
                stride,
                padding,
            }
            | LayerKind::AvgPool {
                kernel,
                stride,
                padding,
            } => {
                let s = one("pool");
                let [c, h, w] = chw(s);
                Shape::chw(
                    c,
                    conv_dim(h, *kernel, *stride, *padding),
                    conv_dim(w, *kernel, *stride, *padding),
                )
            }
            LayerKind::GlobalAvgPool => {
                let s = one("gap");
                let [c, _, _] = chw(s);
                Shape::features(c)
            }
            LayerKind::Dense { out_features } => {
                let s = one("dense");
                let mut dims = s.0.clone();
                *dims.last_mut().expect("dense needs >= 1 dim") = *out_features;
                Shape(dims)
            }
            LayerKind::BatchNorm
            | LayerKind::Relu
            | LayerKind::Gelu
            | LayerKind::Dropout
            | LayerKind::LayerNorm
            | LayerKind::Softmax => one("elementwise").clone(),
            LayerKind::Add => {
                assert!(!inputs.is_empty(), "add needs >= 1 input");
                for s in inputs {
                    assert_eq!(
                        s.0, inputs[0].0,
                        "add requires identical input shapes"
                    );
                }
                inputs[0].clone()
            }
            LayerKind::Concat => {
                assert!(!inputs.is_empty(), "concat needs >= 1 input");
                let first = chw(inputs[0]);
                let mut c_total = 0;
                for s in inputs {
                    let [c, h, w] = chw(s);
                    assert_eq!((h, w), (first[1], first[2]), "concat spatial mismatch");
                    c_total += c;
                }
                Shape::chw(c_total, first[1], first[2])
            }
            LayerKind::Flatten => {
                let s = one("flatten");
                Shape::features(s.numel())
            }
            LayerKind::Embedding { dim, .. } => {
                let s = one("embedding");
                assert_eq!(s.0.len(), 1, "embedding input is a token sequence [T]");
                Shape::seq(s.0[0], *dim)
            }
            LayerKind::SelfAttention { heads } => {
                let s = one("attention");
                assert_eq!(s.0.len(), 2, "attention input is [T, D]");
                assert_eq!(s.0[1] % heads, 0, "D must divide by heads");
                s.clone()
            }
        }
    }

    /// Forward FLOPs per sample (multiply-accumulate counted as 2 FLOPs).
    pub fn flops(&self, inputs: &[&Shape], output: &Shape) -> u64 {
        match self {
            LayerKind::Input => 0,
            LayerKind::Conv2d { kernel, .. } => {
                let [in_c, _, _] = chw(inputs[0]);
                let [out_c, oh, ow] = chw(output);
                2 * (out_c * oh * ow) as u64 * (in_c * kernel * kernel) as u64
            }
            LayerKind::MaxPool { kernel, .. } | LayerKind::AvgPool { kernel, .. } => {
                (output.numel() * kernel * kernel) as u64
            }
            LayerKind::GlobalAvgPool => inputs[0].numel() as u64,
            LayerKind::Dense { out_features } => {
                let in_f = *inputs[0].0.last().unwrap();
                let rows: usize = inputs[0].0[..inputs[0].0.len() - 1].iter().product::<usize>().max(1);
                2 * (rows * in_f * out_features) as u64
            }
            LayerKind::BatchNorm | LayerKind::LayerNorm => 4 * output.numel() as u64,
            LayerKind::Relu | LayerKind::Dropout => output.numel() as u64,
            LayerKind::Gelu | LayerKind::Softmax => 8 * output.numel() as u64,
            LayerKind::Add => (inputs.len().saturating_sub(1) * output.numel()) as u64,
            LayerKind::Concat | LayerKind::Flatten => 0,
            LayerKind::Embedding { .. } => output.numel() as u64, // gather + pos add
            LayerKind::SelfAttention { .. } => {
                let (t, d) = (output.0[0], output.0[1]);
                // QKV projections (3) + output projection (1): 8*T*D^2.
                // Scores + weighted sum: 4*T^2*D.
                (8 * t * d * d + 4 * t * t * d) as u64
            }
        }
    }

    /// Trainable parameter count.
    pub fn params(&self, inputs: &[&Shape], _output: &Shape) -> u64 {
        match self {
            LayerKind::Conv2d {
                out_ch, kernel, ..
            } => {
                let [in_c, _, _] = chw(inputs[0]);
                (*out_ch * (in_c * kernel * kernel + 1)) as u64
            }
            LayerKind::Dense { out_features } => {
                let in_f = *inputs[0].0.last().unwrap();
                (*out_features * (in_f + 1)) as u64
            }
            LayerKind::BatchNorm => {
                let c = inputs[0].0[0];
                2 * c as u64
            }
            LayerKind::LayerNorm => {
                let d = *inputs[0].0.last().unwrap();
                2 * d as u64
            }
            LayerKind::Embedding { vocab, dim } => {
                let t = inputs[0].0[0];
                (*vocab * *dim + t * *dim) as u64 // token + positional tables
            }
            LayerKind::SelfAttention { .. } => {
                let d = inputs[0].0[1];
                (4 * d * d + 4 * d) as u64
            }
            _ => 0,
        }
    }
}

fn chw(s: &Shape) -> [usize; 3] {
    assert_eq!(s.0.len(), 3, "expected [C,H,W] shape, got {:?}", s.0);
    [s.0[0], s.0[1], s.0[2]]
}

fn conv_dim(input: usize, kernel: usize, stride: usize, padding: usize) -> usize {
    assert!(stride > 0, "stride must be positive");
    assert!(
        input + 2 * padding >= kernel,
        "kernel {kernel} larger than padded input {input}+2*{padding}"
    );
    (input + 2 * padding - kernel) / stride + 1
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_shape_and_params() {
        let k = LayerKind::Conv2d {
            out_ch: 64,
            kernel: 3,
            stride: 1,
            padding: 1,
        };
        let input = Shape::chw(3, 32, 32);
        let out = k.infer_shape(&[&input]);
        assert_eq!(out, Shape::chw(64, 32, 32));
        assert_eq!(k.params(&[&input], &out), 64 * (3 * 3 * 3 + 1));
        // 2 * 64*32*32 * 3*3*3 FLOPs
        assert_eq!(k.flops(&[&input], &out), 2 * 64 * 32 * 32 * 27);
    }

    #[test]
    fn strided_conv_shape() {
        let k = LayerKind::Conv2d {
            out_ch: 8,
            kernel: 7,
            stride: 2,
            padding: 3,
        };
        let out = k.infer_shape(&[&Shape::chw(3, 224, 224)]);
        assert_eq!(out, Shape::chw(8, 112, 112));
    }

    #[test]
    fn pool_shapes() {
        let k = LayerKind::MaxPool {
            kernel: 2,
            stride: 2,
            padding: 0,
        };
        assert_eq!(
            k.infer_shape(&[&Shape::chw(16, 32, 32)]),
            Shape::chw(16, 16, 16)
        );
        assert_eq!(
            LayerKind::GlobalAvgPool.infer_shape(&[&Shape::chw(512, 7, 7)]),
            Shape::features(512)
        );
    }

    #[test]
    fn dense_on_features_and_sequences() {
        let k = LayerKind::Dense { out_features: 10 };
        assert_eq!(
            k.infer_shape(&[&Shape::features(128)]),
            Shape::features(10)
        );
        assert_eq!(k.infer_shape(&[&Shape::seq(16, 64)]), Shape::seq(16, 10));
        // Sequence dense multiplies rows.
        let out = Shape::seq(16, 10);
        assert_eq!(k.flops(&[&Shape::seq(16, 64)], &out), 2 * 16 * 64 * 10);
    }

    #[test]
    fn concat_accumulates_channels() {
        let k = LayerKind::Concat;
        let a = Shape::chw(16, 8, 8);
        let b = Shape::chw(24, 8, 8);
        assert_eq!(k.infer_shape(&[&a, &b]), Shape::chw(40, 8, 8));
    }

    #[test]
    #[should_panic(expected = "concat spatial mismatch")]
    fn concat_rejects_spatial_mismatch() {
        LayerKind::Concat.infer_shape(&[&Shape::chw(16, 8, 8), &Shape::chw(16, 4, 4)]);
    }

    #[test]
    #[should_panic(expected = "identical input shapes")]
    fn add_requires_same_shapes() {
        LayerKind::Add.infer_shape(&[&Shape::chw(16, 8, 8), &Shape::chw(8, 8, 8)]);
    }

    #[test]
    fn attention_analytics() {
        let k = LayerKind::SelfAttention { heads: 12 };
        let s = Shape::seq(128, 768);
        let out = k.infer_shape(&[&s]);
        assert_eq!(out, s);
        assert_eq!(k.params(&[&s], &out), 4 * 768 * 768 + 4 * 768);
        assert_eq!(
            k.flops(&[&s], &out),
            (8 * 128 * 768 * 768 + 4 * 128 * 128 * 768) as u64
        );
    }

    #[test]
    fn embedding_params_include_position_table() {
        let k = LayerKind::Embedding {
            vocab: 50257,
            dim: 768,
        };
        let tokens = Shape::features(128);
        let out = k.infer_shape(&[&tokens]);
        assert_eq!(out, Shape::seq(128, 768));
        assert_eq!(k.params(&[&tokens], &out), (50257 * 768 + 128 * 768) as u64);
    }
}
